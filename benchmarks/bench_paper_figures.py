"""Benchmarks mirroring the paper's figures/tables (deliverable d).

Fig. 2 (daxpy), Fig. 4/5 (first-fault strlen), Fig. 6 (linked list), Fig. 8
(VLA scaling: speedup + vectorization-coverage bars), Table 2 analogue
(model-zoo configs + parameter-count fidelity).

CPU wall times of interpret-mode kernels are NOT TPU predictions — they
validate the harness; the architectural claims (VL-invariance, utilization,
scaling) are computed structurally, the way the paper's own Fig. 8 reports
"percentage of vector instructions" alongside modeled speedup.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ffr as F
from repro.core import predicate as P
from repro.core import vla


def _time(fn, *args, iters=3):
    fn(*args)                                   # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_fig2_daxpy(rows):
    """One predicated kernel source at three VLs; tail n=777 of 1024."""
    from repro.kernels.daxpy import daxpy
    rng = np.random.RandomState(0)
    n = 777
    x = jnp.asarray(rng.randn(1024).astype(np.float32))
    y = jnp.asarray(rng.randn(1024).astype(np.float32))
    outs = {}
    for vl in (128, 256, 512):
        us = _time(lambda xx, yy, vl=vl: daxpy(xx, yy, 2.0, n, block=vl), x, y)
        util = n / (vla.pad_to_vl(n, vl))
        outs[vl] = np.asarray(daxpy(x, y, 2.0, n, block=vl))
        rows.append((f"fig2_daxpy_vl{vl}", us, f"lane_util={util:.3f}"))
    # VL-invariance (the Fig. 2 contract)
    assert np.allclose(outs[128], outs[512], rtol=1e-6)
    rows.append(("fig2_daxpy_vl_invariant", 0.0, "identical_across_VL=True"))


def bench_fig5_strlen(rows):
    """First-faulting strlen: work scales with string length / VL."""
    for n, vl in [(1000, 128), (1000, 512), (10000, 512)]:
        buf = np.zeros(n + 64, np.int32)
        buf[:n] = 7
        jb = jnp.asarray(buf)
        us = _time(lambda b, vl=vl: F.strlen(b, 0, vl=vl), jb)
        iters_needed = -(-n // vl)
        rows.append((f"fig5_strlen_n{n}_vl{vl}", us,
                     f"vector_iters={iters_needed}"))
        assert int(F.strlen(jb, 0, vl=vl)) == n


def bench_fig6_linked_list(rows):
    """Scalarized intra-vector sub-loop over a 64-node list."""
    from repro.core import partition as PT
    from repro.core import reductions as R
    rng = np.random.default_rng(0)
    n_nodes, length = 128, 64
    order = rng.permutation(n_nodes)[:length]
    nxt = np.full(n_nodes, -1, np.int32)
    for a, b in zip(order[:-1], order[1:]):
        nxt[a] = b
    vals = rng.integers(0, 1 << 30, n_nodes).astype(np.int32)
    nxt_j, vals_j = jnp.asarray(nxt), jnp.asarray(vals)

    def run(vl):
        res, ptr = jnp.int32(0), jnp.asarray(int(order[0]), jnp.int32)
        for _ in range(length // vl + 2):
            def lane_step(state, p_lane, lane):
                cur, z = state
                return (nxt_j[cur], P.cpy(p_lane, cur, z)), nxt_j[cur] >= 0
            (ptr, zvec), part = PT.serial_subloop(
                P.ptrue(vl), lane_step, (ptr, jnp.zeros(vl, jnp.int32)))
            gathered = jnp.take(vals_j, jnp.clip(zvec, 0, None))
            res = res ^ R.eorv(part, gathered)
            if int(ptr) < 0:
                break
        return int(res)

    want = 0
    p = int(order[0])
    while p != -1:
        want ^= int(vals[p])
        p = nxt[p]
    for vl in (8, 32):
        t0 = time.perf_counter()
        got = run(vl)
        us = (time.perf_counter() - t0) * 1e6
        assert got == want
        rows.append((f"fig6_listxor_vl{vl}", us, f"serial_lanes={vl}"))


def bench_fig8_vla_scaling(rows):
    """The headline figure: modeled speedup vs VL + vectorization coverage.

    For each workload: vector_iterations(VL) = sum over its loops of
    ceil(n_i / VL) (the paper's scaling mechanism), so modeled speedup vs the
    128-wide machine = iters(128)/iters(VL).  'coverage' = fraction of work
    executable under predication (1.0 for our kernels — that is the point of
    the predicate-first design; scalar fallbacks would lower it).
    """
    workloads = {
        # name -> list of (loop trip counts n, coverage)
        "daxpy": ([100_000], 1.0),
        "strlen": ([40_000], 1.0),
        "attention_row": ([4096] * 32, 1.0),
        "moe_dispatch": ([65536 * 8], 1.0),
        "ssd_chunks": ([4096], 1.0),
        "pointer_chase": ([64], 0.05),   # serialized sub-loop: 1 lane/iter
    }
    base_vl = 128
    for name, (loops, cov) in workloads.items():
        base = sum(-(-n // base_vl) for n in loops)
        for vl in (128, 256, 512):
            it = sum(-(-n // vl) for n in loops)
            vec_speed = base / it
            # Amdahl over the non-vectorizable fraction (paper Fig. 8 left group)
            speed = 1.0 / ((1 - cov) + cov / vec_speed)
            rows.append((f"fig8_{name}_vl{vl}", 0.0,
                         f"speedup={speed:.2f};coverage={cov:.2f}"))


def bench_table2_model_zoo(rows):
    """Config fidelity: param counts vs the advertised sizes."""
    from repro.configs import all_arch_names, get_config
    advertised = {
        "llama_3_2_vision_11b": 10.6e9, "olmoe_1b_7b": 6.9e9,
        "moonshot_v1_16b_a3b": 16e9, "stablelm_3b": 2.8e9,
        "command_r_plus_104b": 104e9, "stablelm_12b": 12.1e9,
        "gemma3_27b": 27e9, "zamba2_1_2b": 1.2e9, "mamba2_130m": 0.13e9,
        "seamless_m4t_large_v2": 2.3e9,
    }
    for arch in all_arch_names():
        cfg = get_config(arch)
        n = cfg.param_count()
        adv = advertised[arch]
        ratio = n / adv
        rows.append((f"table2_params_{arch}", 0.0,
                     f"params={n:.3e};advertised={adv:.2e};ratio={ratio:.2f}"))
