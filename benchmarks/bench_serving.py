"""Serving throughput under Poisson traffic: tokens/sec and lane occupancy
for the continuous-batching scheduler vs the static-batch engine, at several
lane capacities — plus a PAGED leg (native paged decode: flash attention
reads K/V through the page table, no dense-view gather on the hot path)
whose pool is sized from ``--paged-mem-frac`` of the dense KV footprint.
At the default fraction 1.0 the paged leg runs at MATCHED memory and the
recorded ``dense_paged_ratio`` (paged / continuous tokens-per-sec) is the
regression guard the CI smoke job gates with ``--min-paged-ratio`` — a
full-view copy reintroduced on the decode path shows up as the ratio
collapsing.  A second record at half memory (``paged_half``) shows the
page-gated admission behavior under real memory pressure.  Emits
``BENCH_serving.json`` so the perf trajectory of the serve path is recorded
per PR.

Scheduler legs run the FUSED step program with the async overlap harvest
(one dispatch + one blocking sync per round); each continuous record carries
``continuous_static_ratio`` (continuous / static tokens-per-sec) plus
per-request TTFT/TPOT p50/p99, and ``--min-continuous-ratio`` gates the
largest capacity's ratio in CI — per-round host dispatch overhead creeping
back into the serve loop shows up as that ratio collapsing.

``--chaos`` adds an OVERLOAD leg: priority bursts with deadlines and a
bounded queue against a pool sized for half the lanes, under a deterministic
``ChaosMonkey`` alloc-failure schedule.  It is a behavior gate, not a speed
number: zero page leaks after drain, ``preemptions > 0`` (the starved
high-priority arrivals actually preempted), and every request finishing as
``done``/``preempted_resumed`` must serve tokens byte-identical to a calm
twin on ample resources — preemption spill/resume is bit-exact.

``--tp-mesh DxM`` adds a tensor-parallel leg: the same trace served through
a mesh-backed engine (lanes sharded over "data", KV-head pools and MLP over
"model").  On the forced host-device CPU mesh this is a STRUCTURE check,
not a speed number: the leg hard-fails unless its dispatch count and token
count match the 1-device continuous leg exactly (mesh sharding must not
reintroduce per-token host syncs into the serve loop).

    PYTHONPATH=src python -m benchmarks.bench_serving [--fast] \
        [--seed 0] [--trace-len 8] [--min-paged-ratio 0.5] \
        [--min-continuous-ratio 0.2]

The arrival trace is Poisson in DECODE-STEP time (the scheduler's clock):
request inter-arrival gaps are exponential with the given rate, so bursts and
lulls both occur — exactly the ragged traffic that makes lane recycling (and
compaction below the occupancy threshold) pay off.  A fraction of requests
share a common "system prompt" prefix, the traffic shape that prefix sharing
converts into skipped prefill work.  ``--seed``/``--trace-len`` pin the trace
for the CI smoke job (deterministic, < 2 min).
"""

from __future__ import annotations

import argparse
import collections
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paging import pages_needed
from repro.dist import collectives as C
from repro.launch.mesh import force_host_devices, make_mesh, parse_mesh
from repro.models import ModelConfig, get_model
from repro.obs import Obs, Tracer
from repro.serve import (
    ChaosConfig,
    ChaosMonkey,
    ContinuousBatchingScheduler,
    FinishReason,
    SamplingParams,
    ServeEngine,
    burst_trace,
)

CFG = dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
           vocab_size=256, param_dtype="float32", compute_dtype="float32")


def poisson_trace(rng, n_requests, rate, prompt_lo, prompt_hi,
                  share_frac=0.0, shared_prefix_len=8):
    """(arrival_step, prompt) pairs with exponential inter-arrival gaps.

    ``share_frac`` of the requests open with one common prefix (a "system
    prompt"), the traffic shape prefix sharing converts into refcount bumps.
    """
    t = 0.0
    out = []
    prefix = rng.randint(1, CFG["vocab_size"], shared_prefix_len)
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate)
        prompt = rng.randint(1, CFG["vocab_size"],
                             rng.randint(prompt_lo, prompt_hi))
        if rng.rand() < share_frac:
            prompt = np.concatenate([prefix, prompt])[:prompt_hi]
        # ragged per-request budgets: co-admitted requests then retire at
        # DIFFERENT rounds, so a donor's prefix pages are still resident when
        # sharers arrive (uniform budgets retire whole admission waves at
        # once and the prefix index would always be empty at lookup time)
        out.append((t, prompt, int(rng.randint(3, 9))))
    return out


def session_trace(rng, n_users, turns, page_size, turn_gap=60.0):
    """Multi-turn conversations: each user's turn t+1 prompt EXTENDS its
    turn t prompt, and turn waves are gapped far enough apart in decode-step
    time that a turn's lanes retire — and their prefix pages leave residency
    — before the follow-up arrives.  This is the traffic shape the host-swap
    eviction tier converts into cross-request session hits; without it every
    follow-up pays full prefill."""
    prompts = {u: rng.randint(1, CFG["vocab_size"],
                              int(rng.randint(page_size, page_size + 5)))
               for u in range(n_users)}
    out = []
    t = 0.0
    for turn in range(turns):
        for u in range(n_users):
            out.append((t + float(rng.rand()), prompts[u].copy(),
                        int(rng.randint(3, 9))))
            if turn + 1 < turns:
                ext = rng.randint(1, CFG["vocab_size"],
                                  int(rng.randint(4, 9)))
                prompts[u] = np.concatenate([prompts[u], ext])
        t += turn_gap
    return out


def bench_capacity(eng, trace, *, capacity, max_len, chunk,
                   compact_threshold, page_size=None, pool_pages=None,
                   sampling=None, prefill_chunk=None, fused=True,
                   overlap=True, host_swap_pages=None, collect=None,
                   obs=None, trace_dir=None, leg="serve"):
    """One scheduler run; ``sampling`` is a per-request SamplingParams
    factory rid -> params (None = greedy).  Steps the scheduler manually so
    per-DECODE-STEP latency percentiles can be reported alongside
    throughput (p99 is the number continuous batching is supposed to hold
    down while admission/compaction churn the lane vector).  Default is the
    fused step program with the async overlap harvest — one dispatch and one
    blocking sync per round.

    The per-leg summary IS the obs registry snapshot: counters/series live
    in the scheduler's registry, latency percentiles come from streaming
    log2 histograms (no stored sample lists), and ``snapshot()`` emits the
    exact key shape BENCH_serving.json promises — every scheduler leg now
    carries every counter (swap/session/prefix keys are 0 where the feature
    is off).  Pass ``obs`` (e.g. with a tracer) to share/record the run;
    with ``trace_dir`` set a fresh tracer is attached and the leg's
    Chrome/Perfetto timeline is exported to ``<trace_dir>/<leg>.json``.
    """
    if obs is None:
        obs = Obs(tracer=Tracer()) if trace_dir else Obs()
    reg = obs.metrics
    # wall-clock latency histograms: decode_step (per-round latency amortized
    # over its decode steps), TTFT (submit -> first token committed to a
    # dispatch), TPOT (first token -> harvest, per subsequent token)
    for name in ("decode_step", "ttft", "tpot"):
        reg.histogram(name, unit="ms", percentiles=(50, 99))
    sched = ContinuousBatchingScheduler(
        eng, capacity=capacity, max_len=max_len, chunk=chunk,
        compact_threshold=compact_threshold, page_size=page_size,
        pool_pages=pool_pages, prefill_chunk=prefill_chunk,
        fused=fused, overlap=overlap, host_swap_pages=host_swap_pages,
        obs=obs)
    for rid, (arrival, prompt, max_new) in enumerate(trace):
        sched.submit(prompt, arrival=arrival, max_new_tokens=max_new,
                     sampling=sampling(rid) if sampling else None)
    t0 = time.perf_counter()
    while sched.queue or (sched.lane_rid >= 0).any():
        ds0 = sched.stats["decode_steps"]
        s0 = time.perf_counter()
        sched.step()
        dt = time.perf_counter() - s0
        ran = sched.stats["decode_steps"] - ds0
        if ran:                      # amortize the round over its decode steps
            for _ in range(ran):
                reg.observe("decode_step", dt / ran * 1e3)
    sched.run()                      # overlap: harvest the final stash
    wall = time.perf_counter() - t0
    results = sched.results
    toks = sum(r["n_generated"] for r in results.values())
    for r in results:
        rt = sched.req_times[r]
        reg.observe("ttft", (rt["first_token"] - rt["submitted"]) * 1e3)
        reg.observe("tpot", (rt["finished"] - rt["first_token"]) * 1e3
                    / max(results[r]["n_generated"] - 1, 1))
    rec = {
        "capacity": capacity,
        "requests": len(results),
        "tokens": int(toks),
        "wall_s": wall,
        "tokens_per_s": toks / wall,
        "lane_efficiency": (sched.stats["active_lane_steps"]
                            / max(sched.stats["lane_steps"], 1)),
    }
    rec.update(reg.snapshot())
    rec["prefix_hit_rate"] = rec["prefix_hits"] / max(len(results), 1)
    if page_size is not None:
        # memory-honest throughput accounting: the KV bytes actually held on
        # device (pools + quantization scale pools) and the mean concurrent
        # lanes each byte buys — narrow pools serve the same occupancy from
        # fewer bytes, which is the whole point of quantized pages
        kv_bytes = sum(int(v.nbytes) for k, v in sched.cache.items()
                       if k.endswith("_pages") or k.endswith("_pages_scale"))
        rec.update({
            "page_size": page_size,
            "pool_pages": sched.pool_pages,
            "page_dtype": eng.page_dtype or "float32",
            "kv_cache_bytes": kv_bytes,
            "lanes_per_byte": rec["mean_occupancy"] * capacity / kv_bytes,
        })
    if host_swap_pages:
        rec.update({
            "host_swap_pages": host_swap_pages,
            "cross_request_hit_rate": (sched.stats["session_hits"]
                                       / max(len(results), 1)),
        })
    if prefill_chunk is not None:
        rec["prefill_chunk"] = prefill_chunk
    if collect is not None:
        for rid, r in results.items():
            collect[rid] = r["tokens"].tolist()
    if trace_dir and obs.tracing:
        os.makedirs(trace_dir, exist_ok=True)
        rec["trace_events"] = obs.export(
            os.path.join(trace_dir, f"{leg}.json"))
    return rec


def bench_static(eng, trace, *, capacity, max_len):
    """Static batching baseline: serve the same requests in fixed batches of
    ``capacity`` (each batch waits for its slowest lane)."""
    prompts = [p for _, p, _ in trace]
    t0 = time.perf_counter()
    toks = 0
    for i in range(0, len(prompts), capacity):
        chunk = prompts[i:i + capacity]
        plen = max(len(p) for p in chunk)
        toks_arr = np.zeros((len(chunk), plen), np.int32)
        lens = np.zeros((len(chunk),), np.int32)
        for j, p in enumerate(chunk):
            toks_arr[j, :len(p)] = p
            lens[j] = len(p)
        res = eng.generate({"tokens": jnp.asarray(toks_arr),
                            "lens": jnp.asarray(lens)}, max_len=max_len)
        toks += int(res["n_generated"].sum())
    wall = time.perf_counter() - t0
    return {"capacity": capacity, "tokens": toks, "wall_s": wall,
            "tokens_per_s": toks / wall}


def bench_overload(eng, reqs, *, capacity, max_len, page_size, pool_pages,
                   max_queue=None, chaos=None, obs=None, trace_dir=None,
                   leg="chaos"):
    """Overload leg: a priority burst trace on a deliberately starved pool,
    optionally under a deterministic :class:`ChaosMonkey`.  Returns the
    per-leg record plus ``{rid: tokens}`` for the calm-twin identity gate.

    Unlike the throughput legs this one measures BEHAVIOR, not speed: the
    record carries the robustness counters (preemptions / shed / cancelled /
    deadline_misses / resume_page_ins), a finish-reason census, injected
    fault counts and ``page_leaks`` (allocator pages still live after
    drain — the number the CI gate pins at zero)."""
    if obs is None:
        obs = Obs(tracer=Tracer()) if trace_dir else Obs()
    sched = ContinuousBatchingScheduler(
        eng, capacity=capacity, max_len=max_len, chunk=1,
        compact_threshold=0.5, page_size=page_size, pool_pages=pool_pages,
        fused=True, overlap=True, max_queue=max_queue, obs=obs)
    monkey = ChaosMonkey(chaos).install(sched) if chaos else None
    for r in reqs:
        sched.submit(r["tokens"], arrival=r["arrival"],
                     priority=r["priority"], deadline=r.get("deadline"))
    t0 = time.perf_counter()
    results = monkey.run(sched) if monkey else sched.run()
    wall = time.perf_counter() - t0
    reasons = collections.Counter(
        r["finish_reason"].value for r in results.values())
    toks = sum(r["n_generated"] for r in results.values())
    rec = {
        "capacity": capacity,
        "pool_pages": pool_pages,
        "max_queue": max_queue,
        "requests": len(results),
        "tokens": int(toks),
        "wall_s": wall,
        "tokens_per_s": toks / wall,
        "page_leaks": int(sched.allocator.live_pages),
        "finish_reasons": {k.value: int(reasons.get(k.value, 0))
                           for k in FinishReason},
    }
    if monkey:
        rec.update({
            "chaos_seed": chaos.seed,
            "chaos_alloc_failures": monkey.alloc_failures,
            "chaos_cancels": monkey.cancels,
            "chaos_corruptions": monkey.corruptions,
        })
    rec.update(obs.metrics.snapshot())
    if trace_dir and obs.tracing:
        os.makedirs(trace_dir, exist_ok=True)
        rec["trace_events"] = obs.export(os.path.join(trace_dir,
                                                      f"{leg}.json"))
    tokens = {rid: (r["tokens"], r["finish_reason"])
              for rid, r in results.items()}
    return rec, tokens


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--requests", "--trace-len", dest="trace_len", type=int,
                    default=None,
                    help="number of requests in the trace (deterministic "
                         "given --seed)")
    ap.add_argument("--seed", type=int, default=0,
                    help="trace RNG seed (fixed trace for the CI smoke job)")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="mean arrivals per decode step")
    ap.add_argument("--share-frac", type=float, default=0.4,
                    help="fraction of requests opening with the common "
                         "system-prompt prefix")
    ap.add_argument("--page-size", type=int, default=8,
                    help="KV page size for the paged leg")
    ap.add_argument("--paged-mem-frac", type=float, default=1.0,
                    help="paged pool size as a fraction of the dense KV "
                         "footprint (capacity * pages-per-lane); 1.0 = "
                         "matched memory, the dense_paged_ratio baseline")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="run the scheduler legs with chunked admission "
                         "prefill at this chunk size")
    ap.add_argument("--page-dtype", choices=["int8", "fp8"], default="int8",
                    help="narrow element type for the QUANTIZED paged leg "
                         "(pools hold narrow bytes + per-slot f32 scales, "
                         "dequantized inside the paged-attention gather)")
    ap.add_argument("--min-quant-lanes-ratio", type=float, default=None,
                    help="exit non-zero unless the quantized leg's lanes-"
                         "per-byte reaches this multiple of the matched-"
                         "memory f32 paged leg's — the CI guard that "
                         "quantized pages actually buy concurrency per "
                         "KV byte")
    ap.add_argument("--session-users", type=int, default=4,
                    help="users in the multi-turn session trace (the host-"
                         "swap leg); 0 disables the leg")
    ap.add_argument("--session-turns", type=int, default=3,
                    help="turns per user in the session trace")
    ap.add_argument("--host-swap-pages", type=int, default=64,
                    help="host LRU swap store capacity (pages) for the "
                         "session leg")
    ap.add_argument("--min-paged-ratio", type=float, default=None,
                    help="exit non-zero unless every matched-memory paged "
                         "leg reaches this fraction of the continuous "
                         "(dense-cache) throughput — the CI regression "
                         "guard against a full-view copy on the hot path")
    ap.add_argument("--min-continuous-ratio", type=float, default=None,
                    help="exit non-zero unless the LARGEST capacity's "
                         "continuous/static throughput ratio reaches this "
                         "floor — the CI regression guard against per-round "
                         "host dispatch overhead creeping back into the "
                         "serve loop (fused step + async harvest)")
    ap.add_argument("--tp-mesh", default=None, metavar="DxM",
                    help="add a tensor-parallel leg: serve the same trace "
                         "through a ServeEngine on a (data, model) mesh of "
                         "this shape (forces DxM host CPU devices when the "
                         "process has fewer).  The leg is gated HARD on "
                         "matching the 1-device continuous leg's dispatch "
                         "count — sharding must not add host syncs")
    ap.add_argument("--psum", choices=C.PSUM_MODES, default="fast",
                    help="psum flavor for shard_map-level collectives")
    ap.add_argument("--chaos", action="store_true",
                    help="overload + fault-injection leg: a priority burst "
                         "on a pool sized for HALF the lanes (preemption "
                         "must fire) with deadlines, a bounded queue and a "
                         "deterministic alloc-failure schedule; gates zero "
                         "page leaks, preemptions > 0 and byte-identity of "
                         "every finished request against a calm twin on "
                         "ample resources")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="ChaosConfig seed: the injected fault schedule is "
                         "a pure function of this (replayable)")
    ap.add_argument("--sampling", action="store_true",
                    help="add a stochastic leg (temperature=0.8, top_p=0.9, "
                         "per-request seed = rid): exercises the per-lane "
                         "predicated sampler deterministically")
    ap.add_argument("--trace-dir", default=None,
                    help="export a Chrome/Perfetto trace_event JSON per "
                         "showcase leg (paged/quantized/session/tp + the "
                         "traced continuous leg) into this directory; "
                         "continuous legs stay untraced so the trace-"
                         "overhead gate compares cleanly")
    ap.add_argument("--max-trace-overhead", type=float, default=None,
                    help="run an extra TRACED continuous leg at the largest "
                         "capacity and exit non-zero unless (a) its tokens/"
                         "dispatches/host_syncs equal the untraced leg's "
                         "exactly (tracing must observe, not perturb) and "
                         "(b) its tokens_per_s loss stays within this "
                         "fraction (0.10 = at most 10%% slower)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)

    n_requests = args.trace_len or (8 if args.fast else 24)
    capacities = [2, 4] if args.fast else [2, 4, 8]
    max_new, max_len = 8, 24

    C.set_psum_mode(args.psum)
    mesh = None
    if args.tp_mesh is not None:
        d, m = parse_mesh(args.tp_mesh)
        # must run before the first device op below initializes the backend
        force_host_devices(d * m)
        mesh = make_mesh((d, m), ("data", "model"))

    cfg = ModelConfig(name="bench-serve", family="dense", **CFG)
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_new_tokens=max_new, stop_token=7)
    # the quantized engine shares params; only its page pools differ (narrow
    # elements + scale pools, dequantized inside the paged gather)
    eng_q = ServeEngine(cfg, params, max_new_tokens=max_new, stop_token=7,
                        page_dtype=args.page_dtype)

    rng = np.random.RandomState(args.seed)
    trace = poisson_trace(rng, n_requests, args.rate, 4, 13,
                          share_frac=args.share_frac,
                          shared_prefix_len=args.page_size)

    record = {"bench": "serving", "requests": n_requests, "rate": args.rate,
              "seed": args.seed, "share_frac": args.share_frac,
              "max_new_tokens": max_new, "cfg": CFG,
              "paged_attn": eng.paged_attn,
              "page_size": args.page_size,
              "page_dtype": args.page_dtype,
              "paged_mem_frac": args.paged_mem_frac,
              "psum_mode": args.psum,
              "continuous": [], "static": [], "paged": [], "paged_half": [],
              "quantized": [], "session": [], "sampled": [], "tp": [],
              "traced": [], "chaos": []}

    def _sampled_params(rid: int):
        # fixed per-request seed (the rid) => the stochastic leg is exactly
        # reproducible run-to-run and across capacities
        return SamplingParams(temperature=0.8, top_p=0.9, seed=rid,
                              greedy=False)
    for cap in capacities:
        # untimed warmup over the FULL trace: the admission prefill shapes
        # are bucketed but still trace-dependent, so replaying the identical
        # trace guarantees the timed run hits only compiled programs
        bench_capacity(eng, trace, capacity=cap, max_len=max_len, chunk=4,
                       compact_threshold=0.5, prefill_chunk=args.prefill_chunk)
        r = bench_capacity(eng, trace, capacity=cap, max_len=max_len,
                           chunk=4, compact_threshold=0.5,
                           prefill_chunk=args.prefill_chunk)
        record["continuous"].append(r)
        bench_static(eng, trace, capacity=cap, max_len=max_len)  # warmup
        s = bench_static(eng, trace, capacity=cap, max_len=max_len)
        record["static"].append(s)
        r["continuous_static_ratio"] = r["tokens_per_s"] / s["tokens_per_s"]
        # paged legs: the pool is an HONEST fraction of the dense KV
        # footprint (dense pages = capacity * pages-per-lane; the +1 trash
        # page is reported, not hidden).  The floor is one lane's worst case
        # — below that a max-size request can never admit.  The matched-
        # memory leg (--paged-mem-frac, default 1.0) carries the
        # dense_paged_ratio regression number; the half-memory leg shows
        # page-gated admission under real pressure.
        per_lane = pages_needed(max_len, args.page_size)
        dense_pages = cap * per_lane
        legs = [("paged", args.paged_mem_frac)]
        # skip the half leg when it would duplicate the main one byte-for-byte
        if (max(int(round(dense_pages * 0.5)), per_lane)
                != max(int(round(dense_pages * args.paged_mem_frac)), per_lane)):
            legs.append(("paged_half", 0.5))
        for leg_name, frac in legs:
            pool = max(int(round(dense_pages * frac)), per_lane)
            bench_capacity(eng, trace, capacity=cap, max_len=max_len, chunk=4,
                           compact_threshold=0.5, page_size=args.page_size,
                           pool_pages=pool, prefill_chunk=args.prefill_chunk)
            p = bench_capacity(eng, trace, capacity=cap, max_len=max_len,
                               chunk=4, compact_threshold=0.5,
                               page_size=args.page_size, pool_pages=pool,
                               prefill_chunk=args.prefill_chunk,
                               trace_dir=args.trace_dir,
                               leg=f"{leg_name}_cap{cap}")
            p["mem_frac"] = frac
            p["dense_pages"] = dense_pages
            p["dense_paged_ratio"] = p["tokens_per_s"] / r["tokens_per_s"]
            record[leg_name].append(p)
        p = record["paged"][-1]
        half = ""
        if len(legs) > 1:
            ph = record["paged_half"][-1]
            half = (f"   paged@half {ph['tokens_per_s']:8.1f} tok/s "
                    f"(ratio {ph['dense_paged_ratio']:.2f}, "
                    f"waits {ph['page_waits']})")
        print(f"capacity={cap:2d}  continuous {r['tokens_per_s']:8.1f} tok/s "
              f"(occ {r['mean_occupancy']:.2f}, "
              f"compactions {r['compactions']}, "
              f"p50/p99 {r['decode_step_p50_ms']:.1f}/"
              f"{r['decode_step_p99_ms']:.1f} ms, "
              f"ttft p50 {r['ttft_p50_ms']:.0f} ms, "
              f"syncs {r['host_syncs']}/{r['rounds']}r, "
              f"c/s {r['continuous_static_ratio']:.2f})   "
              f"static {s['tokens_per_s']:8.1f} tok/s   "
              f"paged@{p['pool_pages']}/{dense_pages}pg "
              f"{p['tokens_per_s']:8.1f} tok/s "
              f"(ratio {p['dense_paged_ratio']:.2f}, "
              f"p50 {p['decode_step_p50_ms']:.1f} ms, "
              f"prefix hits {p['prefix_hits']}/{p['requests']})" + half)
        # quantized leg: the SAME page count as the matched-memory paged leg
        # but narrow pool bytes — occupancy holds while the KV footprint
        # shrinks ~4x, so lanes_per_byte (concurrent lanes per KV byte) is
        # the headline; quant_lanes_ratio is what CI gates
        pool = max(int(round(dense_pages * args.paged_mem_frac)), per_lane)
        bench_capacity(eng_q, trace, capacity=cap, max_len=max_len, chunk=4,
                       compact_threshold=0.5, page_size=args.page_size,
                       pool_pages=pool, prefill_chunk=args.prefill_chunk)
        q = bench_capacity(eng_q, trace, capacity=cap, max_len=max_len,
                           chunk=4, compact_threshold=0.5,
                           page_size=args.page_size, pool_pages=pool,
                           prefill_chunk=args.prefill_chunk,
                           trace_dir=args.trace_dir,
                           leg=f"quantized_cap{cap}")
        q["mem_frac"] = args.paged_mem_frac
        q["dense_paged_ratio"] = q["tokens_per_s"] / r["tokens_per_s"]
        q["quant_lanes_ratio"] = (q["lanes_per_byte"]
                                  / max(p["lanes_per_byte"], 1e-12))
        record["quantized"].append(q)
        print(f"             quantized({q['page_dtype']}) "
              f"{q['tokens_per_s']:8.1f} tok/s "
              f"(kv {q['kv_cache_bytes'] / 1e6:.2f} vs "
              f"{p['kv_cache_bytes'] / 1e6:.2f} MB, "
              f"lanes/byte x{q['quant_lanes_ratio']:.2f})")
        if args.sampling:
            bench_capacity(eng, trace, capacity=cap, max_len=max_len,
                           chunk=4, compact_threshold=0.5,
                           sampling=_sampled_params)       # warmup
            q = bench_capacity(eng, trace, capacity=cap, max_len=max_len,
                               chunk=4, compact_threshold=0.5,
                               sampling=_sampled_params)
            q.update(temperature=0.8, top_p=0.9)
            record["sampled"].append(q)
            print(f"             sampled(T=0.8,p=0.9) "
                  f"{q['tokens_per_s']:8.1f} tok/s "
                  f"(p50/p99 {q['decode_step_p50_ms']:.1f}/"
                  f"{q['decode_step_p99_ms']:.1f} ms)")

    if args.chaos:
        # overload leg: bursts of prompts (every 4th at priority 5, every
        # 5th with a tight deadline) against a pool sized for HALF the
        # lanes and a bounded queue — shed, deadline misses and preemption
        # all fire on this trace by construction.  The calm twin replays
        # the SAME submissions (same rids) on ample pages with no queue
        # bound or chaos; every request the chaos leg finishes as done /
        # preempted_resumed must serve byte-identical tokens.
        cap = capacities[-1]
        per_lane = pages_needed(max_len, args.page_size)
        n_chaos = max(n_requests, 12)
        reqs = burst_trace(n_chaos, prompt_len=9, vocab=CFG["vocab_size"],
                           burst=cap, gap=8.0, seed=args.seed,
                           priority_of=lambda i: 5 if i % 4 == 3 else 0)
        for i, r in enumerate(reqs):
            if i % 5 == 4:
                r["deadline"] = r["arrival"] + 4.0
        # the whole trace is submitted up front (arrivals gate DUE-ness,
        # not queue entry), so the queue bound must leave room for the
        # later high-priority bursts to contend — bound it just under the
        # trace length: the overflow sheds, the rest overloads
        chaos_cfg = ChaosConfig(seed=args.chaos_seed, alloc_fail_rate=0.1)
        kw = dict(capacity=cap, max_len=max_len, page_size=args.page_size)
        bench_overload(eng, reqs, pool_pages=(cap // 2) * per_lane,
                       max_queue=n_chaos - 2, chaos=chaos_cfg, **kw)  # warmup
        ch, got = bench_overload(eng, reqs, pool_pages=(cap // 2) * per_lane,
                                 max_queue=n_chaos - 2, chaos=chaos_cfg, **kw,
                                 trace_dir=args.trace_dir,
                                 leg=f"chaos_cap{cap}")
        calm_reqs = [dict(r, deadline=None) for r in reqs]
        _, calm = bench_overload(eng, calm_reqs, pool_pages=cap * per_lane,
                                 **kw)
        finished = {rid for rid, (_, why) in got.items()
                    if why in (FinishReason.DONE,
                               FinishReason.PREEMPTED_RESUMED)}
        ch["tokens_identical_calm"] = all(
            got[rid][0].tobytes() == calm[rid][0].tobytes()
            for rid in finished)
        record["chaos"].append(ch)
        fr = ch["finish_reasons"]
        print(f"chaos({n_chaos} reqs, burst={cap})  "
              f"preempt {ch['preemptions']} shed {ch['shed']} "
              f"deadline {ch['deadline_misses']} "
              f"cancelled {ch['cancelled']}  "
              f"alloc-faults {ch['chaos_alloc_failures']}  "
              f"leaks {ch['page_leaks']}pg  "
              f"done {fr['done']}+{fr['preempted_resumed']} resumed  "
              f"identical to calm: {ch['tokens_identical_calm']}")
        if (ch["page_leaks"] != 0 or ch["preemptions"] == 0
                or not ch["tokens_identical_calm"]):
            print("FAIL chaos leg: expected zero page leaks, "
                  "preemptions > 0 and byte-identical finished tokens")
            raise SystemExit(1)

    if args.session_users:
        # multi-turn SESSION leg: each user's turn t+1 prompt extends turn
        # t's, and turn waves are gapped so the earlier lane has retired —
        # its prefix pages are off-pool — before the follow-up arrives.  A
        # hit can then only come from the host-swap tier paging the evicted
        # prefix back in.  Two gates ride the leg: cross-request hits must
        # actually occur, and the warm run's greedy tokens must equal the
        # cold (swap-disabled) run byte-for-byte — page-in restores the
        # same pool bytes that were spilled.
        cap = capacities[-1]
        s_max_len = 48
        s_trace = session_trace(np.random.RandomState(args.seed + 1),
                                args.session_users, args.session_turns,
                                args.page_size)
        kw = dict(capacity=cap, max_len=s_max_len, chunk=4,
                  compact_threshold=0.5, page_size=args.page_size,
                  pool_pages=cap * pages_needed(s_max_len, args.page_size))
        cold: dict = {}
        bench_capacity(eng, s_trace, **kw, collect=cold)
        warm: dict = {}
        sess = bench_capacity(eng, s_trace, **kw,
                              host_swap_pages=args.host_swap_pages,
                              collect=warm, trace_dir=args.trace_dir,
                              leg=f"session_cap{cap}")
        follow_ups = args.session_users * (args.session_turns - 1)
        sess.update({
            "users": args.session_users,
            "turns": args.session_turns,
            "follow_up_requests": follow_ups,
            "tokens_identical_cold": warm == cold,
        })
        record["session"].append(sess)
        print(f"session({args.session_users}u x {args.session_turns}t)  "
              f"hits {sess['session_hits']}/{follow_ups} follow-ups "
              f"({sess['session_hit_tokens']} tokens skipped, "
              f"swap out/in {sess['swap_out_pages']}/"
              f"{sess['swap_in_pages']} pages)  "
              f"tokens identical to cold: {sess['tokens_identical_cold']}")
        if sess["session_hits"] == 0 or not sess["tokens_identical_cold"]:
            print("FAIL session leg: expected cross-request hits > 0 with "
                  "byte-identical tokens after page-in")
            raise SystemExit(1)

    if mesh is not None:
        # tensor-parallel leg at the LARGEST capacity: same trace through a
        # mesh-backed engine (lanes over "data", KV heads/MLP over "model").
        # On a forced host-device CPU mesh this measures dispatch structure,
        # not speed — the HARD gate is that the sharded serve loop issues
        # exactly as many dispatches as the 1-device fused leg (sharding must
        # not reintroduce per-token host syncs), and tokens match byte-ness
        # aside, count-for-count.
        cap = capacities[-1]
        eng_tp = ServeEngine(cfg, params, max_new_tokens=max_new,
                             stop_token=7, mesh=mesh)
        bench_capacity(eng_tp, trace, capacity=cap, max_len=max_len, chunk=4,
                       compact_threshold=0.5, prefill_chunk=args.prefill_chunk)
        t = bench_capacity(eng_tp, trace, capacity=cap, max_len=max_len,
                           chunk=4, compact_threshold=0.5,
                           prefill_chunk=args.prefill_chunk,
                           trace_dir=args.trace_dir, leg=f"tp_cap{cap}")
        t["mesh"] = args.tp_mesh
        t["psum_mode"] = args.psum
        base = next(r for r in record["continuous"] if r["capacity"] == cap)
        t["tp_continuous_ratio"] = t["tokens_per_s"] / base["tokens_per_s"]
        record["tp"].append(t)
        print(f"capacity={cap:2d}  tp@{args.tp_mesh} "
              f"{t['tokens_per_s']:8.1f} tok/s "
              f"(ratio {t['tp_continuous_ratio']:.2f}, "
              f"dispatches {t['dispatches']} vs {base['dispatches']}, "
              f"syncs {t['host_syncs']}/{t['rounds']}r)")
        if (t["dispatches"] != base["dispatches"]
                or t["tokens"] != base["tokens"]):
            print(f"FAIL tp leg: dispatches {t['dispatches']} / tokens "
                  f"{t['tokens']} != continuous leg's "
                  f"{base['dispatches']} / {base['tokens']}")
            raise SystemExit(1)
        print(f"tp dispatch count matches continuous at capacity {cap}: ok")

    if args.max_trace_overhead is not None or args.trace_dir:
        # traced continuous leg at the largest capacity vs an UNTRACED twin:
        # the zero-sync telemetry contract, gated.  Tokens, dispatches and
        # host_syncs must match exactly (tracing observes, never perturbs)
        # and the throughput loss must stay under --max-trace-overhead.
        # The twin runs back-to-back with the traced leg and both take their
        # best-of-3 tokens_per_s — wall clocks this short are at the mercy
        # of CI machine noise, and the gate must measure tracing, not a
        # neighboring job.
        cap = capacities[-1]
        kw = dict(capacity=cap, max_len=max_len, chunk=4,
                  compact_threshold=0.5, prefill_chunk=args.prefill_chunk)
        base = tr = None
        for _ in range(3):
            b = bench_capacity(eng, trace, **kw)
            if base is None or b["tokens_per_s"] > base["tokens_per_s"]:
                base = b
            t = bench_capacity(eng, trace, **kw, obs=Obs(tracer=Tracer()),
                               trace_dir=args.trace_dir,
                               leg=f"traced_cap{cap}")
            if tr is None or t["tokens_per_s"] > tr["tokens_per_s"]:
                tr = t
        tr["trace_overhead"] = 1.0 - tr["tokens_per_s"] / base["tokens_per_s"]
        record["traced"].append(tr)
        print(f"capacity={cap:2d}  traced "
              f"{tr['tokens_per_s']:8.1f} tok/s "
              f"(overhead {tr['trace_overhead'] * 100:+.1f}%, "
              f"{tr.get('trace_events', 0)} events)")
        if (tr["tokens"] != base["tokens"]
                or tr["dispatches"] != base["dispatches"]
                or tr["host_syncs"] != base["host_syncs"]):
            print(f"FAIL traced leg: tokens/dispatches/syncs "
                  f"{tr['tokens']}/{tr['dispatches']}/{tr['host_syncs']} != "
                  f"untraced {base['tokens']}/{base['dispatches']}/"
                  f"{base['host_syncs']} — tracing perturbed the serve loop")
            raise SystemExit(1)
        if (args.max_trace_overhead is not None
                and tr["trace_overhead"] > args.max_trace_overhead):
            print(f"FAIL traced leg: tokens_per_s overhead "
                  f"{tr['trace_overhead'] * 100:.1f}% > "
                  f"{args.max_trace_overhead * 100:.0f}%")
            raise SystemExit(1)
        if args.max_trace_overhead is not None:
            print(f"trace overhead within "
                  f"{args.max_trace_overhead * 100:.0f}%: ok")

    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")

    if args.min_paged_ratio is not None:
        bad = [p for p in record["paged"]
               if p["dense_paged_ratio"] < args.min_paged_ratio]
        if bad:
            for p in bad:
                print(f"FAIL capacity={p['capacity']}: paged/continuous "
                      f"ratio {p['dense_paged_ratio']:.2f} < "
                      f"{args.min_paged_ratio} at mem_frac={p['mem_frac']}")
            raise SystemExit(1)
        print(f"paged/continuous ratio >= {args.min_paged_ratio} "
              f"at mem_frac={args.paged_mem_frac}: ok")

    if args.min_quant_lanes_ratio is not None:
        bad = [q for q in record["quantized"]
               if q["quant_lanes_ratio"] < args.min_quant_lanes_ratio]
        if bad:
            for q in bad:
                print(f"FAIL capacity={q['capacity']}: quantized lanes/byte "
                      f"x{q['quant_lanes_ratio']:.2f} < "
                      f"{args.min_quant_lanes_ratio} vs f32 paged")
            raise SystemExit(1)
        print(f"quantized lanes-per-byte >= {args.min_quant_lanes_ratio}x "
              f"f32 paged at matched page count: ok")

    if args.min_continuous_ratio is not None:
        top = record["continuous"][-1]
        if top["continuous_static_ratio"] < args.min_continuous_ratio:
            print(f"FAIL capacity={top['capacity']}: continuous/static "
                  f"ratio {top['continuous_static_ratio']:.2f} < "
                  f"{args.min_continuous_ratio}")
            raise SystemExit(1)
        print(f"continuous/static ratio "
              f"{top['continuous_static_ratio']:.2f} >= "
              f"{args.min_continuous_ratio} "
              f"at capacity {top['capacity']}: ok")


if __name__ == "__main__":
    main()
