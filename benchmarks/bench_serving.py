"""Serving throughput under Poisson traffic: tokens/sec and lane occupancy
for the continuous-batching scheduler vs the static-batch engine, at several
lane capacities.  Emits ``BENCH_serving.json`` so the perf trajectory of the
serve path is recorded per PR.

    PYTHONPATH=src python -m benchmarks.bench_serving [--fast]

The arrival trace is Poisson in DECODE-STEP time (the scheduler's clock):
request inter-arrival gaps are exponential with the given rate, so bursts and
lulls both occur — exactly the ragged traffic that makes lane recycling (and
compaction below the occupancy threshold) pay off.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, get_model
from repro.serve import ContinuousBatchingScheduler, ServeEngine

CFG = dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
           vocab_size=256, param_dtype="float32", compute_dtype="float32")


def poisson_trace(rng, n_requests, rate, prompt_lo, prompt_hi):
    """(arrival_step, prompt) pairs with exponential inter-arrival gaps."""
    t = 0.0
    out = []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate)
        out.append((t, rng.randint(1, CFG["vocab_size"],
                                   rng.randint(prompt_lo, prompt_hi))))
    return out


def bench_capacity(eng, trace, *, capacity, max_len, chunk,
                   compact_threshold):
    sched = ContinuousBatchingScheduler(
        eng, capacity=capacity, max_len=max_len, chunk=chunk,
        compact_threshold=compact_threshold)
    for arrival, prompt in trace:
        sched.submit(prompt, arrival=arrival)
    t0 = time.perf_counter()
    results = sched.run()
    wall = time.perf_counter() - t0
    toks = sum(r["n_generated"] for r in results.values())
    occ = sched.stats["occupancy_trace"]
    lane_eff = (sched.stats["active_lane_steps"]
                / max(sched.stats["lane_steps"], 1))
    return {
        "capacity": capacity,
        "requests": len(results),
        "tokens": int(toks),
        "wall_s": wall,
        "tokens_per_s": toks / wall,
        "mean_occupancy": float(np.mean(occ)) if occ else 0.0,
        "lane_efficiency": lane_eff,
        "compactions": sched.stats["compactions"],
        "rounds": sched.stats["steps"],
    }


def bench_static(eng, trace, *, capacity, max_len):
    """Static batching baseline: serve the same requests in fixed batches of
    ``capacity`` (each batch waits for its slowest lane)."""
    prompts = [p for _, p in trace]
    t0 = time.perf_counter()
    toks = 0
    for i in range(0, len(prompts), capacity):
        chunk = prompts[i:i + capacity]
        plen = max(len(p) for p in chunk)
        toks_arr = np.zeros((len(chunk), plen), np.int32)
        lens = np.zeros((len(chunk),), np.int32)
        for j, p in enumerate(chunk):
            toks_arr[j, :len(p)] = p
            lens[j] = len(p)
        res = eng.generate({"tokens": jnp.asarray(toks_arr),
                            "lens": jnp.asarray(lens)}, max_len=max_len)
        toks += int(res["n_generated"].sum())
    wall = time.perf_counter() - t0
    return {"capacity": capacity, "tokens": toks, "wall_s": wall,
            "tokens_per_s": toks / wall}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="mean arrivals per decode step")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)

    n_requests = args.requests or (8 if args.fast else 24)
    capacities = [2, 4] if args.fast else [2, 4, 8]
    max_new, max_len = 8, 24

    cfg = ModelConfig(name="bench-serve", family="dense", **CFG)
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_new_tokens=max_new, stop_token=7)

    rng = np.random.RandomState(0)
    trace = poisson_trace(rng, n_requests, args.rate, 4, 13)

    record = {"bench": "serving", "requests": n_requests, "rate": args.rate,
              "max_new_tokens": max_new, "cfg": CFG,
              "continuous": [], "static": []}
    for cap in capacities:
        # untimed warmup over the FULL trace: the admission prefill shapes
        # are bucketed but still trace-dependent, so replaying the identical
        # trace guarantees the timed run hits only compiled programs
        bench_capacity(eng, trace, capacity=cap, max_len=max_len, chunk=4,
                       compact_threshold=0.5)
        r = bench_capacity(eng, trace, capacity=cap, max_len=max_len,
                           chunk=4, compact_threshold=0.5)
        record["continuous"].append(r)
        bench_static(eng, trace, capacity=cap, max_len=max_len)  # warmup
        s = bench_static(eng, trace, capacity=cap, max_len=max_len)
        record["static"].append(s)
        print(f"capacity={cap:2d}  continuous {r['tokens_per_s']:8.1f} tok/s "
              f"(occ {r['mean_occupancy']:.2f}, "
              f"compactions {r['compactions']})   "
              f"static {s['tokens_per_s']:8.1f} tok/s")

    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
