"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts each ``while`` body ONCE —
for a scan-over-layers model that undercounts FLOPs/bytes/collectives by the
layer count (verified experimentally; see EXPERIMENTS.md §Roofline
methodology).  This module re-derives costs from the optimized HLO text:

  * computations are parsed into op lists with result types;
  * ``while`` ops multiply their body cost by ``known_trip_count`` (emitted
    by XLA for scan-style loops; fallback: condition-constant parse, else 1);
  * ``fusion``/``call`` ops recurse into their called computations;
  * ``conditional`` takes the max across branches;
  * dot FLOPs = 2 x |result| x |contracting dims| (from operand shapes);
    elementwise/reduce FLOPs = |shape|;
  * collective bytes = result-buffer bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (async -start counted,
    -done skipped).  The per-device HLO means all numbers are per-device.

HBM-byte accounting: ops INSIDE a fusion computation stay in registers/VMEM,
so bytes are charged only at materialization boundaries — each top-level op
(in ENTRY or a while body) charges its result bytes (one write) plus its
operands' bytes (one read per consumer edge); fusion internals contribute
FLOPs but no bytes.  This is the standard "is_scheduled" HBM-traffic model.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DT_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
             "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8,
             "u64": 8, "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1,
             "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'known_trip_count"?:\{"?n"?:"?(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(
    r"(?:true_computation=%?([\w\.\-]+).*?false_computation=%?([\w\.\-]+))"
    r"|branch_computations=\{([^}]*)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "rsqrt",
    "sqrt", "tanh", "logistic", "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "select", "clamp", "compare",
    "and", "or", "xor", "not", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "atan2", "remainder", "cosine", "sine",
    "erf", "cbrt",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def _first_shape_elems(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, 0
    dims = [int(d) for d in m.group(2).split(",") if d]
    n = 1
    for d in dims:
        n *= d
    return dims, n


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0               # rough HBM proxy: op results
    transcendentals: float = 0.0
    collectives: dict = field(default_factory=dict)       # kind -> bytes
    collective_counts: dict = field(default_factory=dict)  # kind -> op count
    collective_max: dict = field(default_factory=dict)     # kind -> max bytes/op

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = (self.collective_counts.get(k, 0.0)
                                         + v * mult)
        for k, v in other.collective_max.items():
            # a single op's transfer size is trip-count invariant
            self.collective_max[k] = max(self.collective_max.get(k, 0.0), v)


@dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    line: str


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[_Op]] = {}
        self._parse(text)
        self.entry = self._find_entry(text)
        self._memo: dict[str, Cost] = {}

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            if cur is None:
                m = _COMP_HDR_RE.match(line.strip())
                if m and line.rstrip().endswith("{"):
                    cur = m.group(1)
                    self.computations[cur] = []
                continue
            if line.startswith("}") or line.strip() == "}":
                cur = None
                continue
            m = _OP_RE.match(line)
            if m:
                self.computations[cur].append(
                    _Op(m.group(1), m.group(2), m.group(3), line))

    def _find_entry(self, text: str) -> str:
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HDR_RE.match(line.strip())
                if m:
                    return m.group(1)
        return next(iter(self.computations))

    # -- cost evaluation ---------------------------------------------------

    def cost(self, comp_name: str | None = None, in_fusion: bool = False) -> Cost:
        comp_name = comp_name or self.entry
        key = (comp_name, in_fusion)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        self._memo[key] = total                # breaks accidental cycles
        ops = {op.name: op for op in self.computations.get(comp_name, [])}
        for op in self.computations.get(comp_name, []):
            total.add(self._op_cost(op, ops, in_fusion))
        return total

    _NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "iota", "partition-id",
                   "replica-id"}

    def _traffic(self, op: _Op, ops: dict) -> float:
        """result write + operand reads (HBM edges of one top-level op)."""
        total = float(_type_bytes(op.type_str))
        for name in self._operands(op):
            if name in ops and ops[name].opcode not in ("constant",):
                total += _type_bytes(ops[name].type_str)
        return total

    def _operands(self, op: _Op) -> list[str]:
        inner = op.line.split(op.opcode + "(", 1)[1]
        depth, out, cur = 1, [], ""
        for ch in inner:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if ch == "," and depth == 1:
                out.append(cur.strip())
                cur = ""
            else:
                cur += ch
        if cur.strip():
            out.append(cur.strip())
        names = []
        for o in out:
            # operand tokens print as either "%name" or "f32[..]{..} %name"
            m = re.search(r"%([\w\.\-]+)", o)
            if m:
                names.append(m.group(1))
        return names

    def _op_cost(self, op: _Op, ops: dict, in_fusion: bool) -> Cost:
        c = Cost()
        oc = op.opcode
        if oc == "while":
            m = _COND_BODY_RE.search(op.line)
            trips = 1
            tm = _TRIP_RE.search(op.line)
            if tm:
                trips = int(tm.group(1))
            elif m:
                cond = m.group(1)
                for cop in self.computations.get(cond, []):
                    if cop.opcode == "constant":
                        cm = re.search(r"constant\((\d+)\)", cop.line)
                        if cm:
                            trips = max(trips, int(cm.group(1)))
            if m:
                c.add(self.cost(m.group(2), in_fusion), trips)
            return c
        if oc in ("fusion", "call", "async-start"):
            m = _CALLS_RE.search(op.line) or re.search(r"to=%?([\w\.\-]+)",
                                                       op.line)
            if m:
                # flops recurse; bytes charge only at this boundary
                c.add(self.cost(m.group(1), in_fusion=True))
            if not in_fusion:
                c.bytes += self._traffic(op, ops)
            return c
        if oc == "conditional":
            m = _BRANCHES_RE.search(op.line)
            if m:
                branches = ([m.group(1), m.group(2)] if m.group(1)
                            else [b.strip().lstrip("%") for b in
                                  m.group(3).split(",")])
                costs = [self.cost(b, in_fusion) for b in branches if b]
                if costs:
                    c.add(max(costs, key=lambda x: x.flops))
            return c

        base = oc[:-6] if oc.endswith("-start") else oc
        if base in _COLLECTIVES and not oc.endswith("-done"):
            b = float(_type_bytes(op.type_str))
            c.collectives[base] = c.collectives.get(base, 0.0) + b
            c.collective_counts[base] = c.collective_counts.get(base, 0.0) + 1
            c.collective_max[base] = max(c.collective_max.get(base, 0.0), b)
            if not in_fusion:
                c.bytes += self._traffic(op, ops)
            return c

        if oc == "dot":
            _, out_elems = _first_shape_elems(op.type_str)
            contract = 1
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
            operands = self._operands(op)
            if m and operands and operands[0] in ops:
                lhs_dims, _ = _first_shape_elems(ops[operands[0]].type_str)
                if lhs_dims:
                    for d in m.group(1).split(","):
                        if d:
                            contract *= lhs_dims[int(d)]
            c.flops += 2.0 * out_elems * contract
        elif oc == "convolution":
            _, out_elems = _first_shape_elems(op.type_str)
            operands = self._operands(op)
            kelems = 1
            if len(operands) > 1 and operands[1] in ops:
                _, kelems = _first_shape_elems(ops[operands[1]].type_str)
            c.flops += 2.0 * out_elems * max(kelems, 1)
        elif oc in ("reduce", "reduce-window"):
            operands = self._operands(op)
            if operands and operands[0] in ops:
                _, in_elems = _first_shape_elems(ops[operands[0]].type_str)
                c.flops += float(in_elems)
        elif oc in _ELEMENTWISE:
            _, out_elems = _first_shape_elems(op.type_str)
            c.flops += float(out_elems)
            if oc in ("exponential", "log", "tanh", "logistic", "rsqrt",
                      "sqrt", "power", "cosine", "sine", "erf"):
                c.transcendentals += float(out_elems)

        if not in_fusion and oc not in self._NO_TRAFFIC:
            c.bytes += self._traffic(op, ops)
        return c


def analyze(hlo_text: str) -> dict:
    mod = HloModule(hlo_text)
    c = mod.cost()
    coll_total = sum(c.collectives.values())
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "transcendentals": c.transcendentals,
        "collective_bytes": dict(c.collectives, total=coll_total),
        "collective_counts": dict(c.collective_counts,
                                  total=sum(c.collective_counts.values())),
        "collective_max_bytes": dict(c.collective_max),
    }


if __name__ == "__main__":
    import sys
    print(json.dumps(analyze(open(sys.argv[1]).read()), indent=2))
