"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh)
from the dry-run's compiled artifacts.

    compute_term    = HLO_FLOPs_per_dev / peak_FLOP/s          (197e12 bf16)
    memory_term     = HLO_bytes_per_dev / HBM_bw               (819e9 B/s)
    collective_term = collective_bytes_per_dev / link_bw       (50e9 B/s)

HLO numbers come from the trip-count-aware analyzer (hlo_analysis.py) because
XLA's cost_analysis counts while bodies once (§Roofline methodology in
EXPERIMENTS.md).  All quantities are per-device (the SPMD module IS the
per-device program), so the spec's "X / (chips x BW)" and our "X_per_dev / BW"
are the same number.  ``bytes`` is an upper-bound traffic proxy (sums op
result bytes incl. fusion internals); see the methodology note.

MODEL_FLOPS: train = 6*N(+active for MoE)*tokens; prefill = 2*N_active*tokens;
decode = 2*N_active*batch (one token) + KV-read bytes dominate memory instead.
"""

from __future__ import annotations

import glob
import json
import os

PEAK = 197e12
HBM = 819e9
LINK = 50e9

_IMPROVE = {
    "compute": ("shard the remaining replicated einsums / cut remat "
                "recompute (dots policy) to shrink HLO FLOPs toward 6ND"),
    "memory": ("shrink resident working set: microbatch harder, sequence-"
               "shard saved carries, quantize/per-layer-alias KV caches"),
    "collective": ("reduce-scatter instead of all-reduce, overlap weight "
                   "gathers with compute (latency-hiding scheduler), "
                   "gradient compression (dist.collectives)"),
}


def model_flops_per_dev(rec):
    seq_batch = {"train_4k": (4096, 256), "prefill_32k": (32768, 32),
                 "decode_32k": (32768, 128), "long_500k": (524288, 1)}
    seq, batch = seq_batch[rec["shape"]]
    n_act = rec["active_params"]
    n_dev = rec["n_devices"]
    if rec["shape"].startswith("train"):
        return 6.0 * n_act * seq * batch / n_dev
    if rec["shape"].startswith("prefill"):
        return 2.0 * n_act * seq * batch / n_dev
    return 2.0 * n_act * batch / n_dev          # decode: one token


def terms(rec):
    c = rec["flops"] / PEAK
    m = rec["hlo_bytes_est"] / HBM
    k = rec["collective_bytes"]["total"] / LINK
    dom = max(("compute", c), ("memory", m), ("collective", k),
              key=lambda t: t[1])[0]
    mf = model_flops_per_dev(rec)
    useful_s = mf / PEAK
    bound_s = max(c, m, k)
    return {
        "compute_s": c, "memory_s": m, "collective_s": k, "dominant": dom,
        "model_flops_per_dev": mf,
        "model_over_hlo": mf / rec["flops"] if rec["flops"] else 0.0,
        "roofline_frac": useful_s / bound_s if bound_s else 0.0,
        "improve": _IMPROVE[dom],
    }


def load(results_dir, tag, mesh):
    recs = []
    for f in sorted(glob.glob(os.path.join(
            results_dir, f"*__{mesh}__{tag}.json"))):
        r = json.load(open(f))
        recs.append(r)
    return recs


def table(results_dir="benchmarks/results/dryrun", tag="opt", mesh="single",
          fmt="md"):
    rows = []
    for r in load(results_dir, tag, mesh):
        if r["status"] != "ok":
            rows.append((r["arch"], r["shape"], None, r.get("reason", "")))
            continue
        rows.append((r["arch"], r["shape"], terms(r), r))
    if fmt == "md":
        out = [f"### Roofline — tag `{tag}`, mesh `{mesh}` "
               f"(seconds per step, per chip)\n",
               "| arch | shape | compute | memory | collective | dominant | "
               "6ND/HLO | roofline-frac | bound by / next move |",
               "|---|---|---|---|---|---|---|---|---|"]
        for arch, shape, t, extra in rows:
            if t is None:
                out.append(f"| {arch} | {shape} | — | — | — | skipped | — | — "
                           f"| {extra[:70]} |")
                continue
            out.append(
                f"| {arch} | {shape} | {t['compute_s']:.3e} | "
                f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
                f"{t['dominant']} | {t['model_over_hlo']:.2f} | "
                f"{t['roofline_frac']:.3f} | {t['improve'][:60]}... |")
        return "\n".join(out)
    return rows


def main():
    import argparse
    p = argparse.ArgumentParser()
    p.add_argument("--tag", default="opt")
    p.add_argument("--mesh", default="single")
    p.add_argument("--dir", default="benchmarks/results/dryrun")
    a = p.parse_args()
    print(table(a.dir, a.tag, a.mesh))


if __name__ == "__main__":
    main()
