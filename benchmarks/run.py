"""Benchmark harness (deliverable d): one function per paper figure/table +
kernel micro-benches + the roofline extraction.  Prints ``name,us_per_call,
derived`` CSV, as required.

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def bench_kernels(rows):
    """Per-kernel interpret-mode micro-benches vs their jnp oracles."""
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ssd_scan import ssd_scan
    from repro.kernels.moe_dispatch import moe_positions

    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(1, 4, 256, 64).astype(np.float32))
    k = jnp.asarray(rng.randn(1, 2, 256, 64).astype(np.float32))
    v = jnp.asarray(rng.randn(1, 2, 256, 64).astype(np.float32))

    def t(fn, *a, iters=2):
        out = fn(*a)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*a)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / iters * 1e6

    flops_attn = 2 * 2 * 1 * 4 * 256 * 256 * 64   # qk+av fwd
    for impl in ("kernel", "xla", "naive"):
        us = t(lambda impl=impl: flash_attention(q, k, v, causal=True,
                                                 impl=impl, bq=128, bk=128))
        rows.append((f"kernel_flash_{impl}", us, f"flops={flops_attn:.2e}"))

    x = jnp.asarray(rng.randn(1, 256, 2, 16).astype(np.float32))
    dt = jnp.asarray((np.abs(rng.randn(1, 256, 2)) * 0.1 + 0.01).astype(np.float32))
    A = jnp.asarray(-np.abs(rng.randn(2)).astype(np.float32) - 0.1)
    B = jnp.asarray(rng.randn(1, 256, 16).astype(np.float32) * 0.3)
    C = jnp.asarray(rng.randn(1, 256, 16).astype(np.float32) * 0.3)
    for impl in ("kernel", "xla"):
        us = t(lambda impl=impl: ssd_scan(x, dt, A, B, C, chunk=64,
                                          impl=impl)[0])
        rows.append((f"kernel_ssd_{impl}", us, "chunk=64"))

    ids = jnp.asarray(rng.randint(0, 16, (512, 2)), jnp.int32)
    for impl in ("kernel", "xla"):
        us = t(lambda impl=impl: moe_positions(ids, 16, impl=impl)[0])
        rows.append((f"kernel_moe_positions_{impl}", us, "T=512,K=2,E=16"))

    from repro.kernels.fadda import fadda
    xs = jnp.asarray(rng.randn(4096).astype(np.float32))
    us = t(lambda: fadda(xs, block=512))
    rows.append(("kernel_fadda", us, "strictly_ordered=True"))


def bench_roofline(rows):
    """Roofline terms per cell from the dry-run JSONs (if present)."""
    import glob
    import json
    from benchmarks import roofline as RL
    found = False
    for f in sorted(glob.glob("benchmarks/results/dryrun/*__single__opt.json")):
        r = json.load(open(f))
        if r["status"] != "ok":
            continue
        found = True
        t = RL.terms(r)
        rows.append((f"roofline_{r['arch']}_{r['shape']}", 0.0,
                     f"compute={t['compute_s']:.3e}s;memory={t['memory_s']:.3e}s;"
                     f"collective={t['collective_s']:.3e}s;dom={t['dominant']};"
                     f"frac={t['roofline_frac']:.3f}"))
    if not found:
        rows.append(("roofline", 0.0,
                     "no dry-run results; run python -m repro.launch.dryrun"))


def main() -> None:
    fast = "--fast" in sys.argv
    from benchmarks import bench_paper_figures as BF
    rows: list = []
    BF.bench_fig2_daxpy(rows)
    BF.bench_fig5_strlen(rows)
    BF.bench_fig6_linked_list(rows)
    BF.bench_fig8_vla_scaling(rows)
    BF.bench_table2_model_zoo(rows)
    if not fast:
        bench_kernels(rows)
    bench_roofline(rows)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
