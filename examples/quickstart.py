"""Quickstart: the paper's three code figures, running as VLA-JAX.

  Fig. 2  daxpy     — predicate-driven loop control (whilelt), one kernel
                      source for every (n, VL)
  Fig. 4/5 strlen   — first-faulting speculative loads + FFR partition
  Fig. 6  list-XOR  — scalarized intra-vector sub-loop (pnext/cpy/ctermeq)
                      + horizontal eorv

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import ffr as F
from repro.core import partition as PT
from repro.core import predicate as P
from repro.core import reductions as R
from repro.kernels.daxpy import daxpy
from repro.kernels.daxpy.ref import daxpy_ref


def fig2_daxpy():
    print("== Fig 2: daxpy, vector-length agnostic ==")
    rng = np.random.RandomState(0)
    n = 1000                                  # NOT a multiple of any VL
    x = jnp.asarray(rng.randn(n).astype(np.float32))
    y = jnp.asarray(rng.randn(n).astype(np.float32))
    want = daxpy_ref(x, y, 2.0, n)
    for vl in (128, 256, 512):                # "128-bit .. 512-bit machines"
        got = daxpy(x, y, 2.0, n, block=vl)
        assert np.allclose(np.asarray(got), np.asarray(want), rtol=1e-5)
        print(f"  VL={vl:4d}: identical result, "
              f"{-(-n // vl)} strip-mined iterations")


def fig5_strlen():
    print("== Fig 5: strlen via first-faulting loads ==")
    buf = np.zeros(1000, np.int32)
    buf[:613] = 65
    for vl in (64, 256):
        got = int(F.strlen(jnp.asarray(buf), 0, vl=vl))
        print(f"  VL={vl:4d}: strlen = {got}")
        assert got == 613
    # the FFR itself, paper Fig. 4: lanes after the first fault are cleared
    base = jnp.arange(8.0)
    vals, ffr = F.ldff(base, jnp.array([0, 1, 100, 3]), P.ptrue(4))
    print(f"  FFR for faulting gather: {ffr.tolist()} (lane 2 faulted)")


def fig6_linked_list():
    print("== Fig 6: linked-list XOR via scalarized sub-loop ==")
    rng = np.random.default_rng(1)
    n_nodes, length, vl = 64, 40, 16
    order = rng.permutation(n_nodes)[:length]
    nxt = np.full(n_nodes, -1, np.int32)
    for a, b in zip(order[:-1], order[1:]):
        nxt[a] = b
    vals = rng.integers(0, 1 << 30, n_nodes).astype(np.int32)
    nxt_j, vals_j = jnp.asarray(nxt), jnp.asarray(vals)

    want, p = 0, int(order[0])
    while p != -1:
        want ^= int(vals[p])
        p = nxt[p]

    res, ptr = jnp.int32(0), jnp.asarray(int(order[0]), jnp.int32)
    rounds = 0
    while int(ptr) >= 0:
        def lane_step(state, p_lane, lane):
            cur, z = state
            return (nxt_j[cur], P.cpy(p_lane, cur, z)), nxt_j[cur] >= 0
        (ptr, zvec), part = PT.serial_subloop(
            P.ptrue(vl), lane_step, (ptr, jnp.zeros(vl, jnp.int32)))
        res = res ^ R.eorv(part, jnp.take(vals_j, jnp.clip(zvec, 0, None)))
        rounds += 1
    print(f"  XOR over {length}-node list in {rounds} vector rounds "
          f"(VL={vl}): {int(res)} == scalar {want}")
    assert int(res) == want


if __name__ == "__main__":
    fig2_daxpy()
    fig5_strlen()
    fig6_linked_list()
    print("quickstart OK")
