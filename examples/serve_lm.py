"""Serving example (deliverable b): batched generation with vector-partitioned
early exit, continuous batching over a lane vector (SVE compact semantics),
and FFR-style speculative decoding — now batched per lane.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import ModelConfig, get_model
from repro.serve import (ContinuousBatchingScheduler, SamplingParams,
                         ServeEngine, speculative_decode)

BASE = dict(family="dense", param_dtype="float32", compute_dtype="float32",
            vocab_size=512)


def main():
    tcfg = ModelConfig(name="target-20m", n_layers=4, d_model=256, n_heads=8,
                       n_kv_heads=4, d_ff=512, **BASE)
    dcfg = ModelConfig(name="draft-2m", n_layers=2, d_model=64, n_heads=4,
                       n_kv_heads=2, d_ff=128, **BASE)
    tparams, _ = get_model(tcfg).init(jax.random.PRNGKey(0), tcfg)
    dparams, _ = get_model(dcfg).init(jax.random.PRNGKey(1), dcfg)

    rng = np.random.RandomState(0)
    prompts = jnp.asarray(rng.randint(1, 512, (4, 16)))
    lens = jnp.array([16, 9, 12, 16], jnp.int32)     # ragged prompts

    print("== batched generation, ragged prompts, early exit ==")
    eng = ServeEngine(tcfg, tparams, max_new_tokens=8, stop_token=7)
    res = eng.generate({"tokens": prompts, "lens": lens})
    for i in range(4):
        n = int(res["n_generated"][i])
        print(f"  req{i} (len {int(lens[i]):2d}): "
              f"{res['tokens'][i, :n].tolist()}"
              f"{'  [stopped]' if not bool(res['active'][i]) else ''}")

    print("== continuous batching: 12 streamed requests over 4 lanes ==")
    sched = ContinuousBatchingScheduler(eng, capacity=4, max_len=28,
                                        chunk=4, compact_threshold=0.5)
    req_rng = np.random.RandomState(1)
    for i in range(12):
        plen = int(req_rng.randint(4, 17))
        sched.submit(req_rng.randint(1, 512, plen),
                     arrival=float(i))          # staggered arrivals
    results = sched.run()
    for rid in sorted(results):
        print(f"  req{rid}: {results[rid]['tokens'].tolist()}")
    occ = sched.stats["occupancy_trace"]
    print(f"  rounds={sched.stats['steps']} "
          f"compactions={sched.stats['compactions']} "
          f"mean occupancy={sum(occ) / max(len(occ), 1):.2f}")

    print("== per-lane heterogeneous sampling (one jitted decode loop) ==")
    # four lanes, four different decoding distributions, ONE compiled
    # program: greedy argmax, creative top-p, tight top-k, and a
    # repetition-penalised lane — each stream reproducible from its own seed
    specs = [None,                                           # greedy
             SamplingParams(temperature=1.0, top_p=0.9, seed=1, greedy=False),
             SamplingParams(temperature=0.7, top_k=8, seed=2, greedy=False),
             SamplingParams(temperature=0.9, repetition_penalty=1.3, seed=3,
                            greedy=False)]
    res_s = eng.generate({"tokens": prompts, "lens": lens}, sampling=specs)
    labels = ["greedy", "top_p=0.9", "top_k=8", "rep_pen=1.3"]
    for i in range(4):
        n = int(res_s["n_generated"][i])
        print(f"  lane{i} [{labels[i]:>10s}]: "
              f"{res_s['tokens'][i, :n].tolist()}")
    assert res_s["tokens"][0].tolist() == res["tokens"][0].tolist(), \
        "greedy lane must be bit-identical to the all-greedy engine"
    rerun = eng.generate({"tokens": prompts, "lens": lens}, sampling=specs)
    assert rerun["tokens"].tolist() == res_s["tokens"].tolist(), \
        "fixed seeds must reproduce the streams exactly"
    print("  greedy lane bit-identical + streams seed-reproducible: True")

    print("== speculative decoding (FFR acceptance) ==")
    out, stats = speculative_decode(tcfg, tparams, dcfg, dparams,
                                    prompts[:1], n_tokens=12, k_draft=4)
    print(f"  tokens: {out.tolist()}")
    print(f"  accepted per round: {stats['accept_counts']} "
          f"(mean {stats['mean_accepted']:.2f} of k={stats['k_draft']})")

    # greedy-equivalence audit (the FFR contract: accepted == target-alone)
    model = get_model(tcfg)
    toks = prompts[:1]
    want = []
    for _ in range(12):
        logits, _ = model.train_logits(tparams, tcfg, {"tokens": toks})
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        want.append(int(nxt[0]))
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    assert out.tolist() == want, "speculative output != target greedy!"
    print("  bit-identical to target-alone greedy decoding: True")

    print("== batched speculative decoding (per-lane FFR partitions) ==")
    outs, bstats = speculative_decode(tcfg, tparams, dcfg, dparams, prompts,
                                      n_tokens=8, k_draft=4, lens=lens)
    for i in range(outs.shape[0]):
        print(f"  lane{i}: {outs[i].tolist()}")
    print(f"  mean accepted across lanes: {bstats['mean_accepted']:.2f} "
          f"of k={bstats['k_draft']}")

    print("== stochastic speculative decoding (rejection sampling) ==")
    # draft == target => q == p => every proposal accepted even under
    # temperature sampling (the rejection ratio is identically 1)
    sp = [SamplingParams(temperature=0.9, top_p=0.95, seed=10 + i,
                         greedy=False) for i in range(4)]
    souts, sstats = speculative_decode(tcfg, tparams, tcfg, tparams, prompts,
                                       n_tokens=8, k_draft=3, lens=lens,
                                       sampling=sp)
    for i in range(souts.shape[0]):
        print(f"  lane{i}: {souts[i].tolist()}")
    print(f"  mean accepted with a perfect draft: "
          f"{sstats['mean_accepted']:.2f} of k={sstats['k_draft']} "
          f"(rejection ratio p/q == 1)")


if __name__ == "__main__":
    main()
