"""End-to-end training driver (deliverable b): data pipeline -> predicated
model -> fused train step -> async checkpointing -> fault-tolerant loop, with
an optional injected fault to demonstrate recovery.

Defaults train a ~15M-param model for 60 steps on CPU in a few minutes; use
``--preset 100m --steps 300`` on real hardware for the paper-scale run.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps N] [--inject-fault]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.data import SyntheticLM
from repro.models import ModelConfig
from repro.runtime import FaultTolerantLoop
from repro.train.step import init_state, make_train_step

PRESETS = {
    "15m": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                vocab_size=8192),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048, vocab_size=32128),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="15m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-fault", action="store_true")
    ap.add_argument("--microbatch", type=int, default=1)
    args = ap.parse_args()

    cfg = ModelConfig(name=f"lm-{args.preset}", family="dense",
                      param_dtype="float32", compute_dtype="float32",
                      **PRESETS[args.preset])
    print(f"model: {cfg.name}  params={cfg.param_count():.3e}")

    state, _ = init_state(jax.random.PRNGKey(0), cfg)
    step_fn = jax.jit(make_train_step(cfg, peak_lr=3e-4, warmup=20,
                                      total=args.steps,
                                      microbatch=args.microbatch),
                      donate_argnums=(0,))

    data = SyntheticLM(cfg.vocab_size, args.seq, seed=0)

    def batch_fn(step):
        tokens, labels, lens = data.batch(step, args.batch)
        return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels),
                "lens": jnp.asarray(lens)}

    faults = {17} if args.inject_fault else set()

    def injector(step):
        if step in faults:
            faults.discard(step)
            raise RuntimeError(f"injected node failure at step {step}")

    loop = FaultTolerantLoop(step_fn, batch_fn, ckpt_dir=args.ckpt_dir,
                             save_every=10)
    t0 = time.time()

    def cb(step, metrics):
        if step % 10 == 0 or step < 3:
            print(f"  step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"({time.time() - t0:.1f}s)")

    state, hist = loop.run(state, args.steps, metrics_cb=cb,
                           fault_injector=injector)
    losses = [l for _, l in hist]
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}  "
          f"recoveries={loop.recoveries}  "
          f"stragglers={len(loop.watchdog.flagged)}")
    assert losses[-1] < losses[0], "training did not reduce loss"


if __name__ == "__main__":
    main()
