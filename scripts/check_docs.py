#!/usr/bin/env python
"""Docs gate: link-check the markdown layer, and assert docs/BENCH.md's
glossary covers every key the serving benchmark actually emits.

    python scripts/check_docs.py                      # link check only
    python scripts/check_docs.py --bench-json BENCH_serving.json

Link check: every relative markdown link in README.md and docs/*.md must
resolve to an existing file, and fragment links (`file.md#anchor` or
`#anchor`) must point at a real heading (GitHub slug rules).

Glossary check (with --bench-json): collect the record's top-level keys
plus every key of every per-leg record, and require each to appear
backtick-quoted in docs/BENCH.md.  Adding a metric to
benchmarks/bench_serving.py without documenting it fails this gate.
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _slugify(heading: str) -> str:
    """GitHub's markdown heading -> anchor slug (the subset we rely on)."""
    text = re.sub(r"[`*_]", "", heading.strip()).lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def _anchors(md_path: Path) -> set:
    return {_slugify(h) for h in HEADING_RE.findall(md_path.read_text())}


def check_links(md_files) -> list:
    errors = []
    for md in md_files:
        text = md.read_text()
        # strip fenced code blocks: bench output / shell snippets aren't links
        text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, fragment = target.partition("#")
            dest = md if not path_part else (md.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link -> "
                              f"{target} ({dest} does not exist)")
                continue
            if fragment and dest.suffix == ".md":
                if fragment not in _anchors(dest):
                    errors.append(f"{md.relative_to(ROOT)}: dead anchor -> "
                                  f"{target} (no heading slugs to "
                                  f"#{fragment})")
    return errors


def bench_keys(record: dict) -> set:
    """Every key the bench emits: top-level + each per-leg record's keys."""
    keys = set(record)
    for value in record.values():
        if isinstance(value, list):
            for rec in value:
                if isinstance(rec, dict):
                    keys.update(rec)
    return keys


def check_glossary(bench_json: Path, glossary_md: Path) -> list:
    record = json.loads(bench_json.read_text())
    glossary = glossary_md.read_text()
    missing = sorted(k for k in bench_keys(record)
                     if f"`{k}`" not in glossary)
    return [f"{glossary_md.relative_to(ROOT)}: undocumented bench key "
            f"`{k}` (emitted by benchmarks/bench_serving.py)"
            for k in missing]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench-json", type=Path, default=None,
                    help="BENCH_serving.json to check glossary coverage "
                         "against (skipped if omitted)")
    args = ap.parse_args()

    md_files = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]
    errors = check_links(md_files)
    print(f"link check: {len(md_files)} files, "
          f"{'ok' if not errors else f'{len(errors)} broken'}")

    if args.bench_json is not None:
        glossary_errors = check_glossary(args.bench_json,
                                         ROOT / "docs" / "BENCH.md")
        n = len(bench_keys(json.loads(args.bench_json.read_text())))
        print(f"glossary check: {n} emitted keys, "
              f"{'ok' if not glossary_errors else f'{len(glossary_errors)} undocumented'}")
        errors += glossary_errors
    else:
        print("glossary check: skipped (no --bench-json)")

    for e in errors:
        print(f"  FAIL {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
