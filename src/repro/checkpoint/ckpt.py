"""Checkpointing: per-leaf .npy files + manifest, atomic commit, async save,
elastic restore (re-shard onto whatever mesh the restart brings up).

Layout:
    <dir>/step_<n>.tmp/...   (being written)
    <dir>/step_<n>/leaf_000.npy ... manifest.json   (committed via rename)

Atomic-rename commit means a fault mid-save never corrupts the latest
checkpoint — the restore path simply picks the highest committed step.
Restore takes an optional (mesh, shardings) pair and uses
``jax.make_array_from_callback`` so each host/device only materializes its
shard — elastic scaling: the on-disk format is mesh-free.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_paths(tree):
    paths = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append((jax.tree_util.keystr(path), leaf))
    return paths


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    """Blocking save with atomic commit.  Returns the committed directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    manifest = {"step": int(step), "n_leaves": len(leaves),
                "treedef": str(treedef),
                "keys": [k for k, _ in _leaf_paths(tree)]}
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"),
                np.asarray(jax.device_get(leaf)))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic commit
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name[5:]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, like: Any, *, step: Optional[int] = None,
                       shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``like``; optionally shard-on-load.

    ``shardings``: optional pytree of NamedShardings (same structure) — each
    device materializes only its shard (elastic re-mesh on restore).
    """
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = jax.tree.flatten(like)
    assert manifest["n_leaves"] == len(leaves_like), "structure mismatch"
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves_like))
    out = []
    for i, (leaf_like, sh) in enumerate(zip(leaves_like, shard_leaves)):
        arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
        assert tuple(arr.shape) == tuple(leaf_like.shape), (
            f"leaf {i}: {arr.shape} vs {leaf_like.shape}")
        if sh is not None:
            out.append(jax.make_array_from_callback(
                arr.shape, sh, lambda idx, a=arr: a[idx]))
        else:
            out.append(jnp.asarray(arr, dtype=leaf_like.dtype))
    return jax.tree.unflatten(treedef, out), step


class AsyncCheckpointer:
    """Background-thread checkpoint writer (double-buffered).

    ``save`` device_gets synchronously (cheap vs a training step), then the
    serialization + fsync happens off-thread; ``wait`` joins the last write.
    """

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(n[5:]) for n in os.listdir(self.ckpt_dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)
