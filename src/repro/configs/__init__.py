"""Architecture registry: one module per assigned architecture."""

from __future__ import annotations

import importlib

ARCHS = [
    "llama_3_2_vision_11b",
    "olmoe_1b_7b",
    "moonshot_v1_16b_a3b",
    "stablelm_3b",
    "command_r_plus_104b",
    "stablelm_12b",
    "gemma3_27b",
    "zamba2_1_2b",
    "mamba2_130m",
    "seamless_m4t_large_v2",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}


def get_config(name: str, **overrides):
    mod_name = _ALIAS.get(name, name).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.config()
    return cfg.replace(**overrides) if overrides else cfg


def all_arch_names():
    return list(ARCHS)
