"""command-r-plus-104b [dense] — 64L d12288 96H (kv8) dff33792 v256000.
Cohere style: parallel attention+MLP block, layernorm, no bias, tied
embeddings.  [hf:CohereForAI/c4ai-command-r-v01; unverified]"""

from repro.models import ModelConfig

from .shapes import LM_SHAPES


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b", family="dense",
        n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
        d_ff=33792, vocab_size=256000, head_dim=128,
        norm="layernorm", activation="swiglu", parallel_block=True,
        tie_embeddings=True, rope_theta=75000000.0,
        shapes=LM_SHAPES, skip_long_context=True,
    )
