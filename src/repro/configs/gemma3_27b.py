"""gemma3-27b [dense] — 62L d5376 32H (kv16) dff21504 v262144.
5:1 local:global attention (every 6th layer global), local window 1024,
qk-norm, geglu, sqrt(d) embedding scale.  [hf:google/gemma-3-1b-pt; unverified]"""

from repro.models import ModelConfig

from .shapes import LM_SHAPES


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
        d_ff=21504, vocab_size=262144, head_dim=128,
        norm="rmsnorm", activation="geglu", qk_norm=True, embed_scale=True,
        local_window=1024, local_global_period=6, rope_theta=1000000.0,
        shapes=LM_SHAPES, skip_long_context=True,
    )
