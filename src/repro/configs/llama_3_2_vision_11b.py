"""llama-3.2-vision-11b [vlm] — 40L d4096 32H (kv8) dff14336 v128256.
Cross-attn image layers every 5th (8 of 40, HF cross_attention_layers).
Vision frontend is a stub: input_specs provides patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""

from repro.models import ModelConfig

from .shapes import LM_SHAPES


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=128256, head_dim=128,
        norm="rmsnorm", activation="swiglu", rope_theta=500000.0,
        cross_attn_group=5, n_cross_tokens=1024,
        shapes=LM_SHAPES, skip_long_context=True,
    )
