"""mamba2-130m [ssm] — 24L d768, attention-free SSD, ssm_state=128, v50280
(padded to 50304 for lane/TP divisibility — logits masked).  Runs long_500k.
[arXiv:2405.21060; unverified]"""

from repro.models import ModelConfig

from .shapes import LM_SHAPES


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab_size=50280, tie_embeddings=True,
        ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_conv_width=4,
        norm="rmsnorm",
        shapes=LM_SHAPES, skip_long_context=False,
    )
