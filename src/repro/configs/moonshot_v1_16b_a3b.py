"""moonshot-v1-16b-a3b [moe] — kimi/Moonlight: 48L d2048 16H (kv16) dff1408,
64 routed experts top-6 + 2 shared experts, first layer dense (11264).
[hf:moonshotai/Moonlight-16B-A3B; hf]"""

from repro.models import ModelConfig

from .shapes import LM_SHAPES


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=163840,
        n_experts=64, top_k=6, capacity_factor=1.25,
        first_k_dense=1, d_ff_dense=11264, n_shared_experts=2,
        norm="rmsnorm", activation="swiglu", rope_theta=50000.0,
        shapes=LM_SHAPES, skip_long_context=True,
    )
