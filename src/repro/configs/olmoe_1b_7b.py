"""olmoe-1b-7b [moe] — 16L d2048 16H (kv16) dff1024 v50304, 64 experts top-8.
[arXiv:2409.02060; hf]"""

from repro.models import ModelConfig

from .shapes import LM_SHAPES


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab_size=50304,
        n_experts=64, top_k=8, capacity_factor=1.25,
        norm="rmsnorm", activation="swiglu", qk_norm=True,
        rope_theta=10000.0,
        shapes=LM_SHAPES, skip_long_context=True,
    )
