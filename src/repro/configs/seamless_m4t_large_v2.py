"""seamless-m4t-large-v2 [audio] — enc-dec backbone, 24 encoder + 24 decoder
layers, d1024 16H (kv16) dff8192 v256206 (padded 256256).  The speech
frontend is a STUB: input_specs provides precomputed frame embeddings.
[arXiv:2308.11596; hf]"""

from repro.models import ModelConfig

from .shapes import LM_SHAPES


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="encdec",
        n_layers=48, n_enc_layers=24, n_dec_layers=24,
        d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab_size=256206,
        norm="layernorm", activation="gelu", use_bias=True,
        rope_theta=10000.0,
        shapes=LM_SHAPES, skip_long_context=True,
    )
