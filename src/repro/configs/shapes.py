"""The assigned LM input-shape set (seq_len, global_batch, kind) per cell."""

LM_SHAPES = (
    ("train_4k", 4096, 256, "train"),
    ("prefill_32k", 32768, 32, "prefill"),
    ("decode_32k", 32768, 128, "decode"),
    ("long_500k", 524288, 1, "long"),
)
