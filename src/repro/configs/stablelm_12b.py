"""stablelm-12b [dense] — 40L d5120 32H (kv8) dff13824 v100352.
[hf:stabilityai/stablelm-2-1_6b; hf]"""

from repro.models import ModelConfig

from .shapes import LM_SHAPES


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
        d_ff=13824, vocab_size=100352,
        norm="layernorm", activation="swiglu",
        partial_rotary_factor=0.25, rope_theta=10000.0,
        shapes=LM_SHAPES, skip_long_context=True,
    )
