"""stablelm-3b [dense] — 32L d2560 32H (kv32) dff6912 v50304.
StableLM-2 family: layernorm, partial rotary 25%.
[hf:stabilityai/stablelm-2-1_6b; unverified]"""

from repro.models import ModelConfig

from .shapes import LM_SHAPES


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b", family="dense",
        n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=6912, vocab_size=50304,
        norm="layernorm", activation="swiglu",
        partial_rotary_factor=0.25, rope_theta=10000.0,
        shapes=LM_SHAPES, skip_long_context=True,
    )
