"""zamba2-1.2b [hybrid] — 38 Mamba2 layers d2048 + SHARED attention block
(32H kv32, dff8192) applied every 6 SSM layers; ssm_state=64, v32000.
Runs long_500k (constant-memory SSM decode; the shared block keeps one KV
slot per application point).  [arXiv:2411.15242; hf]"""

from repro.models import ModelConfig

from .shapes import LM_SHAPES


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32000,
        ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_conv_width=4,
        shared_attn_period=6,
        norm="rmsnorm", activation="swiglu", rope_theta=10000.0,
        shapes=LM_SHAPES, skip_long_context=False,
    )
