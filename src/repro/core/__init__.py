"""repro.core — the paper's contribution (ARM SVE, IEEE Micro 2017) as a
composable JAX library: vector-length agnosticism, predicate-centric
execution, first-faulting speculation, vector partitioning and horizontal
operations, adapted for TPU execution at lane/chip/cluster scales.
"""

from . import ffr, paging, partition, predicate, reductions, vla  # noqa: F401

__all__ = ["vla", "predicate", "partition", "ffr", "reductions", "paging"]
