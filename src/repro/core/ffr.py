"""First-faulting loads and the FFR (SVE C4), adapted to TPU/XLA.

TPU has no faulting vector loads and no per-lane trap machinery, so the
*mechanism* (suppress the trap, poison the FFR) cannot be ported.  What we
preserve is the architectural *contract* of paper §2.3.3:

  * a speculative vector load may touch addresses that are not known-safe;
  * lanes from the first "faulting" lane onward are NOT architecturally
    loaded, and a first-fault register (FFR) reports the safe partition;
  * the first active lane is never suppressed — a genuine fault there is the
    caller's to handle (in JAX: it reads the fill value and the FFR bit for
    lane 0 is False, which the caller must check — there is no OS trap).

"Faults" on TPU are bounds violations / invalid pages of a software-managed
address space (paged KV caches, ragged token buffers, linked structures laid
out in arrays), checked explicitly.  ``mode=fill`` gathers make the
speculative access side-effect free, exactly like a suppressed load.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from . import partition as PT
from . import predicate as P

Array = jax.Array


def fault_oob(indices: Array, lower, upper) -> Array:
    """Fault predicate for a [lower, upper) address window."""
    return (indices < lower) | (indices >= upper)


def ldff(
    base: Array,
    indices: Array,
    p: Array,
    *,
    fault: Array | None = None,
    lower: int = 0,
    upper: int | None = None,
    fill=0,
) -> tuple[Array, Array]:
    """First-faulting gather: ``values, ffr = ldff(base, idx, p)``.

    - ``base``: 1-D (or leading-dims) source array, gathered on axis 0.
    - ``indices``: lane vector of element addresses.
    - ``p``: governing predicate.
    - ``fault``: optional explicit per-lane fault predicate; defaults to an
      out-of-bounds check against [lower, upper or len(base)).

    Returns values (zeroing predication on non-loaded lanes: they read as
    ``fill``) and the FFR partition: governed lanes strictly before the first
    faulting active lane (``brkb`` over the fault predicate).  Matches the
    paper's Fig. 4 semantics: A[2] invalid => FFR = [T, T, F, F].
    """
    if upper is None:
        upper = base.shape[0]
    if fault is None:
        fault = fault_oob(indices, lower, upper)
    ffr = PT.brkb(p, fault)
    safe_idx = jnp.clip(indices, 0, base.shape[0] - 1)
    vals = jnp.take(base, safe_idx, axis=0, mode="fill", fill_value=fill)
    vals = P.zeroing(ffr, vals) if fill == 0 else jnp.where(
        P._bcast(ffr, vals.ndim), vals, jnp.asarray(fill, vals.dtype))
    return vals, ffr


def ldff_contiguous(base: Array, start, p: Array, *, valid_len=None, fill=0):
    """First-faulting contiguous load from ``base[start : start+VL]``.

    The ``ldff1b`` of the paper's strlen example: lanes past the end of the
    valid region "fault" and clear the FFR from that point on.
    """
    vl = p.shape[-1]
    idx = jnp.asarray(start) + jnp.arange(vl, dtype=jnp.int32)
    upper = base.shape[0] if valid_len is None else valid_len
    return ldff(base, idx, p, lower=0, upper=upper, fill=fill)


def speculative_loop(
    body: Callable,
    start_state,
    p0: Array,
    max_iters: int,
):
    """The setffr/ldff/rdffr/brk loop skeleton of paper Fig. 5c.

    ``body(state, p) -> (state, p_continue, done)`` performs one speculative
    vector step under governing predicate ``p`` (typically: ldff, compute on
    the FFR partition, detect the data-dependent exit).  The loop re-enters
    while ``done`` is false, with the governing predicate advanced by the
    number of consumed lanes — the caller's state carries the stream position.
    """

    def cond(carry):
        _, _, done, it = carry
        return (~done) & (it < max_iters)

    def step(carry):
        state, p, _, it = carry
        state, p, done = body(state, p)
        return state, p, done, it + 1

    state, p, done, _ = jax.lax.while_loop(
        cond, step, (start_state, p0, jnp.bool_(False), jnp.int32(0))
    )
    return state, p, done


def strlen(buf: Array, s: int | Array = 0, *, valid_len=None, vl: int = 128) -> Array:
    """Paper Fig. 5: vectorized strlen via first-faulting loads.

    ``buf`` is a byte array (int8/uint8/int32 values; 0 terminates).  Faithful
    to Fig. 5c: ldff1b -> rdffr -> cmpeq -> brkbs -> incp, looping on b.last.
    Works for strings whose terminator lies beyond ``valid_len`` only if a
    terminator exists within bounds; otherwise returns the bounded length —
    the same behaviour as the real code (which would trap on lane 0).
    """
    valid_len = buf.shape[0] if valid_len is None else valid_len

    def body(e, _p):
        p0 = P.ptrue(vl)
        vals, ffr = ldff_contiguous(buf, e, p0, valid_len=valid_len, fill=-1)
        is_nul = ffr & (vals == 0)                     # cmpeq under p1=ffr
        before_nul = PT.brkb(ffr, is_nul)              # brkbs
        e = e + P.cntp(before_nul)                     # incp
        # b.last: continue while the LAST lane of the partition is active
        # (no NUL found and no fault in this vector's view).
        done = ~P.last(before_nul)
        return e, p0, done

    e, _, _ = speculative_loop(body, jnp.asarray(s, jnp.int32), P.ptrue(vl),
                               max_iters=(buf.shape[0] // max(vl, 1)) + 2)
    return e - jnp.asarray(s, jnp.int32)
