"""Page-table indirection (SVE §2.3.3 gather/scatter) for non-contiguous state.

SVE's gather-load / scatter-store instructions make non-contiguous physical
layout a first-class citizen: code addresses LOGICAL elements while the
hardware indirects through an index vector.  This module applies the same
contract to decode caches: a *page pool* holds fixed-size physical pages and a
per-lane *page table* (an index vector) maps logical token blocks to physical
pages.  Every access below is a pure ``jnp.take`` / ``.at[].set`` — the JAX
spelling of gather-load / scatter-store — so the compiler sees plain index
arithmetic and the serving layer can reshuffle physical placement (allocation,
reuse, prefix sharing) without ever moving the logical view.

Layout conventions
------------------
* a **pool** is ``lead + (P, Hkv, page_size, D)`` — ``lead`` is any tuple of
  leading axes (layer stacks etc.), ``P`` the physical page count.
* a **page table** is ``(B, n_pages) int32`` — lane b's logical block j lives
  in physical page ``table[b, j]``.  One page id spans ALL pools of a cache
  (every layer's K and V for that token block), so refcounting is per page.
* the dense layout is the degenerate case ``page_size == max_len``,
  ``table[b] == [b]`` — one private page per lane, gather is the identity
  permutation.

Quantized pools (SVE §2.3.3 extending/truncating loads)
-------------------------------------------------------
SVE's extending gather-loads keep NARROW data in memory and widen it in
register at the point of use; truncating scatter-stores narrow on the way
back.  The quantized pool layout is the same contract: pools hold int8 (or
fp8) elements, and a **scale pool** of shape ``lead + (P, Hkv, page_size)``
rides alongside under ``<key>_pages_scale`` — one f32 absmax scale per
(page, head, slot), i.e. per token row.  Per-slot (rather than whole-page)
scales make the single-token decode scatter an exact local operation: the
new token quantizes against its own absmax, no read-modify-write of the
page's other rows.  ``gather_pages(..., scale=)`` widens in the gather —
the same ``jnp.take`` walks both pools — and ``scatter_page_q`` /
``scatter_block_q`` truncate on store.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def pages_needed(length: int, page_size: int) -> int:
    """How many pages cover ``length`` tokens (the strip-mine trip count)."""
    return -(-length // page_size)


def page_whilelt(lens, n_pages: int, page_size: int) -> Array:
    """Page-granular ``whilelt``: page j of a lane is live iff its first
    token position ``j * page_size`` is below the lane's valid length.

    Shape ``(*lens, n_pages)`` bool — the governing predicate for page-table
    walks (which table entries are meaningful) exactly as ``whilelt`` governs
    element strips.
    """
    first_tok = jnp.arange(n_pages, dtype=jnp.int32) * page_size
    return first_tok < jnp.asarray(lens, jnp.int32)[..., None]


def gather_pages(pool: Array, table: Array, *, n_lead: int = 0,
                 scale: Array | None = None) -> Array:
    """Gather-load the dense logical view of a paged tensor.

    pool: ``lead + (P, Hkv, page_size, D)``; table: ``(B, n_pages) int32``.
    Returns ``lead + (B, Hkv, n_pages * page_size, D)`` where lane b's logical
    positions ``[j*ps, (j+1)*ps)`` read physical page ``table[b, j]`` — the
    SVE gather-load with the page table as the index vector.  Out-of-range
    page ids clamp (JAX gather semantics); garbage beyond a lane's valid
    length is masked downstream by ``kv_lens`` predicates, mirroring the
    dense cache's garbage-beyond-pos contract.

    With ``scale`` (the ``lead + (P, Hkv, page_size)`` per-slot scale pool of
    a quantized cache) this is an *extending* gather-load: the narrow pool
    elements widen to f32 in the returned view, ``q * scale`` per token row —
    the same index vector drives both walks.
    """
    b, n_pages = table.shape
    lead = pool.shape[:n_lead]
    hkv, ps, d = pool.shape[n_lead + 1:]
    ids = table.reshape(-1).astype(jnp.int32)
    flat = jnp.take(pool, ids, axis=n_lead)
    out = flat.reshape(lead + (b, n_pages, hkv, ps, d))
    out = jnp.moveaxis(out, n_lead + 1, n_lead + 2)     # lead+(B,Hkv,n,ps,D)
    if scale is not None:
        sc = jnp.take(scale, ids, axis=n_lead).reshape(lead + (b, n_pages, hkv, ps))
        sc = jnp.moveaxis(sc, n_lead + 1, n_lead + 2)   # lead+(B,Hkv,n,ps)
        out = out.astype(sc.dtype) * sc[..., None]
    return out.reshape(lead + (b, hkv, n_pages * ps, d))


def scatter_page(pool: Array, page_ids: Array, offsets: Array, values: Array,
                 *, n_lead: int = 0) -> Array:
    """Scatter-store one element per lane into its page.

    ``values`` is ``lead + (B, Hkv, D)``; lane b's element lands at
    ``pool[..., page_ids[b], :, offsets[b], :]``.  Targets must be distinct
    across lanes (the serving invariant: every lane's write position lives in
    a page it owns exclusively — shared prefix pages are immutable).
    """
    lead = pool.shape[:n_lead]
    hkv, d = pool.shape[n_lead + 1], pool.shape[n_lead + 3]
    b = page_ids.shape[0]
    pool2 = pool.reshape((-1,) + pool.shape[n_lead:])            # (lead*,P,Hkv,ps,D)
    vals = values.reshape((-1, b, hkv, d))                       # (lead*,B,Hkv,D)
    vals = jnp.moveaxis(vals, 0, 1)                              # (B,lead*,Hkv,D)
    idx = (slice(None), page_ids.astype(jnp.int32), slice(None),
           offsets.astype(jnp.int32), slice(None))
    # the two advanced indices are non-adjacent, so the broadcast lane axis
    # leads the indexed result — vals is laid out to match
    pool2 = pool2.at[idx].set(vals.astype(pool.dtype))
    return pool2.reshape(lead + pool.shape[n_lead:])


def scatter_block(pool: Array, page_ids: Array, blocks: Array,
                  *, n_lead: int = 0) -> Array:
    """Scatter-store whole pages: ``blocks`` is ``(K,) + lead + (Hkv, ps, D)``
    written to physical pages ``page_ids (K,)`` — the admission path copying
    freshly prefilled K/V blocks into their allocated pages.
    """
    pool_m = jnp.moveaxis(pool, n_lead, 0)                       # (P,)+lead+...
    pool_m = pool_m.at[page_ids.astype(jnp.int32)].set(blocks.astype(pool.dtype))
    return jnp.moveaxis(pool_m, 0, n_lead)


def gather_block(pool: Array, page_ids: Array, *, n_lead: int = 0) -> Array:
    """Gather whole pages: returns ``(K,) + lead + (Hkv, ps, D)`` for pages
    ``page_ids (K,)`` — used to seed a prefill sub-batch with resident shared
    prefix pages."""
    return jnp.moveaxis(jnp.take(pool, page_ids.astype(jnp.int32), axis=n_lead),
                        n_lead, 0)


def alloc_pools(spec: dict, pool_pages: int, page_size: int, kv_heads: int,
                head_dim: int, dtype, page_dtype=None) -> dict:
    """Allocate the zeroed page pools for a family's paged-cache spec.

    ``spec`` maps cache key -> tuple of leading (layer-stack) dims; the pool
    for key ``k`` is stored under ``k + "_pages"`` with shape
    ``lead + (pool_pages, kv_heads, page_size, head_dim)``.

    ``page_dtype`` (``"int8"`` / ``"fp8"`` or a dtype) switches the pool to
    narrow in-memory storage: elements are held quantized and an f32 scale
    pool of shape ``lead + (pool_pages, kv_heads, page_size)`` is allocated
    under ``k + "_pages_scale"`` (one absmax scale per token row).
    """
    qdt = resolve_page_dtype(page_dtype)
    pool_dt = qdt if qdt is not None else dtype
    pools = {}
    for key, lead in spec.items():
        pools[key + "_pages"] = jnp.zeros(
            tuple(lead) + (pool_pages, kv_heads, page_size, head_dim), pool_dt)
        if qdt is not None:
            pools[key + "_pages_scale"] = jnp.zeros(
                tuple(lead) + (pool_pages, kv_heads, page_size), jnp.float32)
    return pools


# --- quantization: narrow-in-memory pools, widened in the gather ------------

_QUANT_NAMES = {"int8": "int8", "fp8": "float8_e4m3fn",
                "float8_e4m3fn": "float8_e4m3fn"}


def resolve_page_dtype(page_dtype):
    """Normalize a ``--page-dtype`` value to a jnp dtype (or None for full
    precision).  Accepts ``"int8"``, ``"fp8"``/``"float8_e4m3fn"``, a dtype,
    or None."""
    if page_dtype is None:
        return None
    if isinstance(page_dtype, str):
        name = _QUANT_NAMES.get(page_dtype)
        if name is None:
            raise ValueError(f"unknown page_dtype {page_dtype!r}; "
                             f"expected one of {sorted(_QUANT_NAMES)}")
        if name == "float8_e4m3fn" and not hasattr(jnp, "float8_e4m3fn"):
            raise ValueError("fp8 pages need a jax with jnp.float8_e4m3fn")
        page_dtype = getattr(jnp, name)
    dt = jnp.dtype(page_dtype)
    if not is_quant_dtype(dt):
        raise ValueError(f"page_dtype {dt} is not a supported narrow type")
    return dt


def is_quant_dtype(dtype) -> bool:
    """True for the narrow in-memory element types pools may quantize to."""
    dt = jnp.dtype(dtype)
    return dt == jnp.dtype(jnp.int8) or dt.name.startswith("float8")


def quant_max(dtype) -> float:
    """Largest representable magnitude of a narrow pool dtype — absmax maps
    onto this, the quantized analogue of the widest in-register value."""
    dt = jnp.dtype(dtype)
    if dt == jnp.dtype(jnp.int8):
        return 127.0
    return float(jnp.finfo(dt).max)


def quantize_block(values: Array, dtype) -> tuple[Array, Array]:
    """Truncating store: quantize ``values (..., D)`` to ``dtype`` with one
    absmax scale per row.  Returns ``(q (..., D) dtype, scale (...,) f32)``
    with ``q * scale ≈ values``; all-zero rows get scale 0 (and decode to 0).
    """
    v = values.astype(jnp.float32)
    qmax = quant_max(dtype)
    absmax = jnp.max(jnp.abs(v), axis=-1)
    scale = absmax / qmax
    safe = jnp.where(scale > 0, scale, 1.0)
    q = v / safe[..., None]
    if jnp.dtype(dtype) == jnp.dtype(jnp.int8):
        q = jnp.round(q)
    # clip in all cases: float rounding in the division can land a hair past
    # qmax, which would saturate int8 wrongly and overflow fp8 (no inf) to nan
    q = jnp.clip(q, -qmax, qmax)
    return q.astype(dtype), scale.astype(jnp.float32)


def dequantize(q: Array, scale: Array) -> Array:
    """Extending load: widen ``q (..., D)`` by its per-row ``scale (...,)``."""
    return q.astype(scale.dtype) * scale[..., None]


def scatter_page_q(pool: Array, scale: Array, page_ids: Array, offsets: Array,
                   values: Array, *, n_lead: int = 0) -> tuple[Array, Array]:
    """Quantizing ``scatter_page``: truncate one f32 element per lane into a
    narrow pool, storing its absmax scale in the scale pool at the same
    (page, offset) — the decode-step write of a quantized cache.  Returns the
    updated ``(pool, scale)``.
    """
    q, sc = quantize_block(values, pool.dtype)       # lead+(B,Hkv,D) / (B,Hkv)
    pool = scatter_page(pool, page_ids, offsets, q, n_lead=n_lead)
    lead = scale.shape[:n_lead]
    b = page_ids.shape[0]
    hkv = scale.shape[n_lead + 1]
    scale2 = scale.reshape((-1,) + scale.shape[n_lead:])      # (lead*,P,Hkv,ps)
    vals = sc.reshape((-1, b, hkv))                           # (lead*,B,Hkv)
    vals = jnp.moveaxis(vals, 0, 1)                           # (B,lead*,Hkv)
    idx = (slice(None), page_ids.astype(jnp.int32), slice(None),
           offsets.astype(jnp.int32))
    # non-adjacent advanced indices: the broadcast lane axis leads, as in
    # scatter_page
    scale2 = scale2.at[idx].set(vals.astype(scale.dtype))
    return pool, scale2.reshape(lead + scale.shape[n_lead:])


def scatter_block_q(pool: Array, scale: Array, page_ids: Array, blocks: Array,
                    *, n_lead: int = 0) -> tuple[Array, Array]:
    """Quantizing ``scatter_block``: truncate whole f32 pages
    ``(K,) + lead + (Hkv, ps, D)`` into a narrow pool, with per-slot scales
    landing in the scale pool — the admission path of a quantized cache.
    Returns the updated ``(pool, scale)``.
    """
    q, sb = quantize_block(blocks, pool.dtype)
    return (scatter_block(pool, page_ids, q, n_lead=n_lead),
            scatter_block(scale, page_ids, sb, n_lead=n_lead))
