"""Page-table indirection (SVE §2.3.3 gather/scatter) for non-contiguous state.

SVE's gather-load / scatter-store instructions make non-contiguous physical
layout a first-class citizen: code addresses LOGICAL elements while the
hardware indirects through an index vector.  This module applies the same
contract to decode caches: a *page pool* holds fixed-size physical pages and a
per-lane *page table* (an index vector) maps logical token blocks to physical
pages.  Every access below is a pure ``jnp.take`` / ``.at[].set`` — the JAX
spelling of gather-load / scatter-store — so the compiler sees plain index
arithmetic and the serving layer can reshuffle physical placement (allocation,
reuse, prefix sharing) without ever moving the logical view.

Layout conventions
------------------
* a **pool** is ``lead + (P, Hkv, page_size, D)`` — ``lead`` is any tuple of
  leading axes (layer stacks etc.), ``P`` the physical page count.
* a **page table** is ``(B, n_pages) int32`` — lane b's logical block j lives
  in physical page ``table[b, j]``.  One page id spans ALL pools of a cache
  (every layer's K and V for that token block), so refcounting is per page.
* the dense layout is the degenerate case ``page_size == max_len``,
  ``table[b] == [b]`` — one private page per lane, gather is the identity
  permutation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def pages_needed(length: int, page_size: int) -> int:
    """How many pages cover ``length`` tokens (the strip-mine trip count)."""
    return -(-length // page_size)


def page_whilelt(lens, n_pages: int, page_size: int) -> Array:
    """Page-granular ``whilelt``: page j of a lane is live iff its first
    token position ``j * page_size`` is below the lane's valid length.

    Shape ``(*lens, n_pages)`` bool — the governing predicate for page-table
    walks (which table entries are meaningful) exactly as ``whilelt`` governs
    element strips.
    """
    first_tok = jnp.arange(n_pages, dtype=jnp.int32) * page_size
    return first_tok < jnp.asarray(lens, jnp.int32)[..., None]


def gather_pages(pool: Array, table: Array, *, n_lead: int = 0) -> Array:
    """Gather-load the dense logical view of a paged tensor.

    pool: ``lead + (P, Hkv, page_size, D)``; table: ``(B, n_pages) int32``.
    Returns ``lead + (B, Hkv, n_pages * page_size, D)`` where lane b's logical
    positions ``[j*ps, (j+1)*ps)`` read physical page ``table[b, j]`` — the
    SVE gather-load with the page table as the index vector.  Out-of-range
    page ids clamp (JAX gather semantics); garbage beyond a lane's valid
    length is masked downstream by ``kv_lens`` predicates, mirroring the
    dense cache's garbage-beyond-pos contract.
    """
    b, n_pages = table.shape
    lead = pool.shape[:n_lead]
    hkv, ps, d = pool.shape[n_lead + 1:]
    flat = jnp.take(pool, table.reshape(-1).astype(jnp.int32), axis=n_lead)
    out = flat.reshape(lead + (b, n_pages, hkv, ps, d))
    out = jnp.moveaxis(out, n_lead + 1, n_lead + 2)     # lead+(B,Hkv,n,ps,D)
    return out.reshape(lead + (b, hkv, n_pages * ps, d))


def scatter_page(pool: Array, page_ids: Array, offsets: Array, values: Array,
                 *, n_lead: int = 0) -> Array:
    """Scatter-store one element per lane into its page.

    ``values`` is ``lead + (B, Hkv, D)``; lane b's element lands at
    ``pool[..., page_ids[b], :, offsets[b], :]``.  Targets must be distinct
    across lanes (the serving invariant: every lane's write position lives in
    a page it owns exclusively — shared prefix pages are immutable).
    """
    lead = pool.shape[:n_lead]
    hkv, d = pool.shape[n_lead + 1], pool.shape[n_lead + 3]
    b = page_ids.shape[0]
    pool2 = pool.reshape((-1,) + pool.shape[n_lead:])            # (lead*,P,Hkv,ps,D)
    vals = values.reshape((-1, b, hkv, d))                       # (lead*,B,Hkv,D)
    vals = jnp.moveaxis(vals, 0, 1)                              # (B,lead*,Hkv,D)
    idx = (slice(None), page_ids.astype(jnp.int32), slice(None),
           offsets.astype(jnp.int32), slice(None))
    # the two advanced indices are non-adjacent, so the broadcast lane axis
    # leads the indexed result — vals is laid out to match
    pool2 = pool2.at[idx].set(vals.astype(pool.dtype))
    return pool2.reshape(lead + pool.shape[n_lead:])


def scatter_block(pool: Array, page_ids: Array, blocks: Array,
                  *, n_lead: int = 0) -> Array:
    """Scatter-store whole pages: ``blocks`` is ``(K,) + lead + (Hkv, ps, D)``
    written to physical pages ``page_ids (K,)`` — the admission path copying
    freshly prefilled K/V blocks into their allocated pages.
    """
    pool_m = jnp.moveaxis(pool, n_lead, 0)                       # (P,)+lead+...
    pool_m = pool_m.at[page_ids.astype(jnp.int32)].set(blocks.astype(pool.dtype))
    return jnp.moveaxis(pool_m, 0, n_lead)


def gather_block(pool: Array, page_ids: Array, *, n_lead: int = 0) -> Array:
    """Gather whole pages: returns ``(K,) + lead + (Hkv, ps, D)`` for pages
    ``page_ids (K,)`` — used to seed a prefill sub-batch with resident shared
    prefix pages."""
    return jnp.moveaxis(jnp.take(pool, page_ids.astype(jnp.int32), axis=n_lead),
                        n_lead, 0)


def alloc_pools(spec: dict, pool_pages: int, page_size: int, kv_heads: int,
                head_dim: int, dtype) -> dict:
    """Allocate the zeroed page pools for a family's paged-cache spec.

    ``spec`` maps cache key -> tuple of leading (layer-stack) dims; the pool
    for key ``k`` is stored under ``k + "_pages"`` with shape
    ``lead + (pool_pages, kv_heads, page_size, head_dim)``.
    """
    return {key + "_pages": jnp.zeros(tuple(lead) + (pool_pages, kv_heads,
                                                     page_size, head_dim), dtype)
            for key, lead in spec.items()}
