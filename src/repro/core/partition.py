"""Vector partitioning & dynamic exits (SVE C5) and scalarized sub-loops (C6).

SVE handles uncounted loops (``do { .. } while``, ``break``) by computing a
*partition* of the vector bounded by the break condition (``brka``/``brkb``)
and only architecturally performing side effects inside the partition.  The
framework uses the same algebra for:

  * batched decode with per-request stop tokens (a batch of requests is a
    vector; finished requests become inactive lanes),
  * speculative-decoding acceptance (accept draft tokens up to the first
    mismatch — a ``brka`` over the match predicate),
  * loop-carried dependencies serialized in place (``pnext`` sub-loops).
"""

from __future__ import annotations

from typing import Callable, TypeVar

import jax
import jax.numpy as jnp

from . import predicate as P

Array = jax.Array
T = TypeVar("T")


def brkb(p_gov: Array, cond: Array) -> Array:
    """Break-BEFORE partition: active lanes of ``p_gov`` strictly before the
    first lane where ``cond`` holds (within the governing predicate).

    SVE ``brkb``.  Lanes at/after the break (and inactive governing lanes) are
    cleared.  If no active lane satisfies ``cond`` the result equals p_gov.
    """
    hit = p_gov & cond
    seen = jnp.cumsum(hit.astype(jnp.int32), axis=-1) > 0   # at or after first hit
    return p_gov & ~seen


def brka(p_gov: Array, cond: Array) -> Array:
    """Break-AFTER partition: active lanes up to and INCLUDING the first
    ``cond`` lane (SVE ``brka``)."""
    hit = p_gov & cond
    before = jnp.cumsum(hit.astype(jnp.int32), axis=-1) - hit.astype(jnp.int32)
    return p_gov & (before == 0)


def brkpb(p_gov: Array, p_prev_partition: Array, cond: Array) -> Array:
    """Propagating break (SVE ``brkpb``): empty if the previous partition
    already broke (its last governing lane is inactive), else ``brkb``."""
    carried = P.last(p_prev_partition)          # previous partition reached the end
    return jnp.where(carried[..., None], brkb(p_gov, cond), jnp.zeros_like(p_gov))


def partitioned_while(
    cond_fn: Callable[[T, Array], Array],
    body_fn: Callable[[T, Array], T],
    init: T,
    p0: Array,
):
    """Run ``body_fn`` under a monotonically-shrinking active partition.

    The vector-partitioning loop idiom of paper §2.3.4, lifted to a combinator:
    each iteration computes per-lane break conditions via ``cond_fn(state, p)``
    (True = lane wants to CONTINUE), the active partition is intersected, and
    the loop exits when no lane remains active.  ``body_fn`` must be
    predication-correct: it receives the current partition and must not
    architecturally update inactive lanes (use ``P.merging``).

    Returns (final_state, final_partition).
    """

    def loop_cond(carry):
        _, p = carry
        return jnp.any(p)

    def loop_body(carry):
        state, p = carry
        keep = cond_fn(state, p)
        p = p & keep
        state = jax.lax.cond(jnp.any(p), lambda s: body_fn(s, p), lambda s: s, state)
        return state, p

    return jax.lax.while_loop(loop_cond, loop_body, (init, p0))


def serial_subloop(
    p_gov: Array,
    step_fn: Callable[[T, Array, Array], tuple[T, Array]],
    init: T,
    max_iters: int | None = None,
):
    """Scalarized intra-vector sub-loop (paper §2.3.5, Fig. 6).

    Visits the active lanes of ``p_gov`` one at a time in element order, the
    way SVE's ``pnext``/``cpy`` serialize loop-carried dependencies in place.
    ``step_fn(state, p_lane, lane_index)`` handles one lane and returns
    ``(state, continue?)`` where the scalar ``continue?`` is the ``ctermeq``
    -style early-termination test.  Returns (state, p_visited).
    """
    vl = p_gov.shape[-1]
    max_iters = vl if max_iters is None else max_iters

    def loop_cond(carry):
        _, p_cur, _visited, cont, it = carry
        return cont & jnp.any(p_cur) & (it < max_iters)

    def loop_body(carry):
        state, p_cur, visited, _, it = carry
        lane = jnp.argmax(p_cur)
        state, cont = step_fn(state, p_cur, lane)
        return state, P.pnext(p_gov, p_cur), visited | p_cur, cont, it + 1

    p_first = P.pfirst(p_gov)
    state, _, visited, _, _ = jax.lax.while_loop(
        loop_cond, loop_body,
        (init, p_first, jnp.zeros_like(p_gov), jnp.bool_(True), jnp.int32(0)),
    )
    return state, visited


# ---------------------------------------------------------------------------
# Lane permutation (SVE §2.3.4/§2.3.5: compact / splice / lasta / lastb)
# ---------------------------------------------------------------------------
#
# These are the data movements that make the partition algebra *useful* at
# serving scale: once a partition has gone ragged (finished requests = inactive
# lanes), ``compact`` squeezes the survivors into the lowest-numbered lanes and
# ``splice`` refills the tail from a second vector — both pure index gathers,
# so a continuous-batching scheduler can keep the lane vector dense without
# recompilation (the VLA contract applied to traffic instead of loops).

def compact_perm(p: Array) -> Array:
    """Lane permutation realising SVE ``compact``: active lane indices first
    (in ascending order), inactive lane indices after (also in order).

    Shape (*batch, VL) int32.  ``x[..., compact_perm(p)]`` densifies the
    active lanes; applying the same permutation to every per-lane side table
    (and to each cache array along its batch axis — see
    ``repro.models.gather_lanes``) keeps request state consistent.
    """
    # stable argsort of the "inactive" flag: active (0) lanes first, original
    # relative order preserved on both sides.
    return jnp.argsort(~p, axis=-1, stable=True).astype(jnp.int32)


def compact(p: Array, x: Array, fill=None) -> Array:
    """SVE ``compact``: copy the active elements of ``x`` to the
    lowest-numbered lanes; remaining lanes read as ``fill`` (0 when None,
    matching the architected zeroing of the tail).

    Operates on the trailing axis; ``p`` broadcasts against leading axes.
    """
    perm = compact_perm(p)
    out = jnp.take_along_axis(x, jnp.broadcast_to(perm, jnp.broadcast_shapes(p.shape, x.shape)), axis=-1)
    n_active = jnp.sum(p.astype(jnp.int32), axis=-1, keepdims=True)
    lane = jnp.arange(x.shape[-1], dtype=jnp.int32)
    tail = lane >= n_active
    fill_v = jnp.zeros((), x.dtype) if fill is None else jnp.asarray(fill, x.dtype)
    return jnp.where(tail, fill_v, out)


def splice(p: Array, a: Array, b: Array) -> Array:
    """SVE ``splice``: the contiguous segment of ``a`` from the FIRST to the
    LAST active lane of ``p`` is copied to the low lanes of the result; the
    remaining lanes are filled with the lowest elements of ``b``.  With an
    empty predicate the result is ``b`` unchanged.

    Together with ``compact`` this is the admission path of continuous
    batching: ``splice(active_after_compact, survivors, newcomers)`` densely
    packs old and new requests into one vector without data-dependent shapes.
    """
    vl = a.shape[-1]
    lane = jnp.arange(vl, dtype=jnp.int32)
    any_p = jnp.any(p, axis=-1, keepdims=True)
    first = jnp.argmax(p, axis=-1)[..., None]                    # first active
    last = (vl - 1) - jnp.argmax(jnp.flip(p, axis=-1), axis=-1)[..., None]
    seg_len = jnp.where(any_p, last - first + 1, 0)
    from_a = lane < seg_len
    a_idx = jnp.clip(first + lane, 0, vl - 1)
    b_idx = jnp.clip(lane - seg_len, 0, vl - 1)
    shp = jnp.broadcast_shapes(p.shape, a.shape, b.shape)
    a_part = jnp.take_along_axis(jnp.broadcast_to(a, shp),
                                 jnp.broadcast_to(a_idx, shp), axis=-1)
    b_part = jnp.take_along_axis(jnp.broadcast_to(b, shp),
                                 jnp.broadcast_to(b_idx, shp), axis=-1)
    return jnp.where(from_a, a_part, b_part)


def lastb(p: Array, x: Array) -> Array:
    """SVE ``lastb``: extract the LAST active element of ``x``; with no active
    lane, the last element (lane VL-1) is returned — the architected
    "previous vector's final element" convention that lets a strip-mined loop
    carry its conditionally-updated scalar across iterations.
    """
    vl = x.shape[-1]
    idx = jnp.where(jnp.any(p, axis=-1),
                    (vl - 1) - jnp.argmax(jnp.flip(p, axis=-1), axis=-1),
                    vl - 1)
    return jnp.take_along_axis(x, idx[..., None].astype(jnp.int32), axis=-1)[..., 0]


def lasta(p: Array, x: Array) -> Array:
    """SVE ``lasta``: the element AFTER the last active one (wrapping to lane
    0 past the end, and with an empty predicate selecting lane 0)."""
    vl = x.shape[-1]
    nxt = jnp.where(jnp.any(p, axis=-1),
                    ((vl - 1) - jnp.argmax(jnp.flip(p, axis=-1), axis=-1) + 1) % vl,
                    0)
    return jnp.take_along_axis(x, nxt[..., None].astype(jnp.int32), axis=-1)[..., 0]


def accept_prefix(match: Array, p_gov: Array | None = None) -> Array:
    """Speculative-acceptance partition: lanes up to and including the first
    mismatch... no — up to the LAST consecutively-matching lane.

    For speculative decoding: ``match[i]`` says draft token i agreed with the
    verifier.  The accepted partition is the maximal prefix of matches — i.e.
    ``brkb`` on the negated match predicate.  The first rejected lane is where
    the verifier's own token is substituted (handled by the caller), mirroring
    the FFR contract where the first faulting lane is retried architecturally.
    """
    if p_gov is None:
        p_gov = jnp.ones_like(match)
    return brkb(p_gov, ~match)
