"""Vector partitioning & dynamic exits (SVE C5) and scalarized sub-loops (C6).

SVE handles uncounted loops (``do { .. } while``, ``break``) by computing a
*partition* of the vector bounded by the break condition (``brka``/``brkb``)
and only architecturally performing side effects inside the partition.  The
framework uses the same algebra for:

  * batched decode with per-request stop tokens (a batch of requests is a
    vector; finished requests become inactive lanes),
  * speculative-decoding acceptance (accept draft tokens up to the first
    mismatch — a ``brka`` over the match predicate),
  * loop-carried dependencies serialized in place (``pnext`` sub-loops).
"""

from __future__ import annotations

from typing import Callable, TypeVar

import jax
import jax.numpy as jnp

from . import predicate as P

Array = jax.Array
T = TypeVar("T")


def brkb(p_gov: Array, cond: Array) -> Array:
    """Break-BEFORE partition: active lanes of ``p_gov`` strictly before the
    first lane where ``cond`` holds (within the governing predicate).

    SVE ``brkb``.  Lanes at/after the break (and inactive governing lanes) are
    cleared.  If no active lane satisfies ``cond`` the result equals p_gov.
    """
    hit = p_gov & cond
    seen = jnp.cumsum(hit.astype(jnp.int32), axis=-1) > 0   # at or after first hit
    return p_gov & ~seen


def brka(p_gov: Array, cond: Array) -> Array:
    """Break-AFTER partition: active lanes up to and INCLUDING the first
    ``cond`` lane (SVE ``brka``)."""
    hit = p_gov & cond
    before = jnp.cumsum(hit.astype(jnp.int32), axis=-1) - hit.astype(jnp.int32)
    return p_gov & (before == 0)


def brkpb(p_gov: Array, p_prev_partition: Array, cond: Array) -> Array:
    """Propagating break (SVE ``brkpb``): empty if the previous partition
    already broke (its last governing lane is inactive), else ``brkb``."""
    carried = P.last(p_prev_partition)          # previous partition reached the end
    return jnp.where(carried[..., None], brkb(p_gov, cond), jnp.zeros_like(p_gov))


def partitioned_while(
    cond_fn: Callable[[T, Array], Array],
    body_fn: Callable[[T, Array], T],
    init: T,
    p0: Array,
):
    """Run ``body_fn`` under a monotonically-shrinking active partition.

    The vector-partitioning loop idiom of paper §2.3.4, lifted to a combinator:
    each iteration computes per-lane break conditions via ``cond_fn(state, p)``
    (True = lane wants to CONTINUE), the active partition is intersected, and
    the loop exits when no lane remains active.  ``body_fn`` must be
    predication-correct: it receives the current partition and must not
    architecturally update inactive lanes (use ``P.merging``).

    Returns (final_state, final_partition).
    """

    def loop_cond(carry):
        _, p = carry
        return jnp.any(p)

    def loop_body(carry):
        state, p = carry
        keep = cond_fn(state, p)
        p = p & keep
        state = jax.lax.cond(jnp.any(p), lambda s: body_fn(s, p), lambda s: s, state)
        return state, p

    return jax.lax.while_loop(loop_cond, loop_body, (init, p0))


def serial_subloop(
    p_gov: Array,
    step_fn: Callable[[T, Array, Array], tuple[T, Array]],
    init: T,
    max_iters: int | None = None,
):
    """Scalarized intra-vector sub-loop (paper §2.3.5, Fig. 6).

    Visits the active lanes of ``p_gov`` one at a time in element order, the
    way SVE's ``pnext``/``cpy`` serialize loop-carried dependencies in place.
    ``step_fn(state, p_lane, lane_index)`` handles one lane and returns
    ``(state, continue?)`` where the scalar ``continue?`` is the ``ctermeq``
    -style early-termination test.  Returns (state, p_visited).
    """
    vl = p_gov.shape[-1]
    max_iters = vl if max_iters is None else max_iters

    def loop_cond(carry):
        _, p_cur, _visited, cont, it = carry
        return cont & jnp.any(p_cur) & (it < max_iters)

    def loop_body(carry):
        state, p_cur, visited, _, it = carry
        lane = jnp.argmax(p_cur)
        state, cont = step_fn(state, p_cur, lane)
        return state, P.pnext(p_gov, p_cur), visited | p_cur, cont, it + 1

    p_first = P.pfirst(p_gov)
    state, _, visited, _, _ = jax.lax.while_loop(
        loop_cond, loop_body,
        (init, p_first, jnp.zeros_like(p_gov), jnp.bool_(True), jnp.int32(0)),
    )
    return state, visited


def accept_prefix(match: Array, p_gov: Array | None = None) -> Array:
    """Speculative-acceptance partition: lanes up to and including the first
    mismatch... no — up to the LAST consecutively-matching lane.

    For speculative decoding: ``match[i]`` says draft token i agreed with the
    verifier.  The accepted partition is the maximal prefix of matches — i.e.
    ``brkb`` on the negated match predicate.  The first rejected lane is where
    the verifier's own token is substituted (handled by the caller), mirroring
    the FFR contract where the first faulting lane is retried architecturally.
    """
    if p_gov is None:
        p_gov = jnp.ones_like(match)
    return brkb(p_gov, ~match)
