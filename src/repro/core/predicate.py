"""Predicate-centric execution (SVE C2/C3) as pure-JAX mask algebra.

SVE governs every vector op with a predicate register and derives loop control
from predicates (``whilelt`` + NZCV condition overloading, Table 1 of the
paper).  JAX is functional, so predicates are boolean arrays (SSA values, not
registers) and the NZCV conditions are explicit scalar reductions.

All functions are jit-safe, shape-polymorphic in the Python sense (static
shapes at trace time), and operate on the trailing axis unless noted.  The
"implicit least- to most-significant element order" of SVE predicates maps to
ascending array index order.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


# --------------------------------------------------------------------------
# Predicate constructors
# --------------------------------------------------------------------------

def ptrue(vl: int, dtype=jnp.bool_) -> Array:
    """All-active predicate (SVE ``ptrue``)."""
    return jnp.ones((vl,), dtype=dtype)


def pfalse(vl: int, dtype=jnp.bool_) -> Array:
    """All-inactive predicate (SVE ``pfalse``)."""
    return jnp.zeros((vl,), dtype=dtype)


def whilelt(start, limit, vl: int) -> Array:
    """p[i] = (start + i) < limit  — SVE ``whilelt`` (signed compare).

    The paper's predicate-driven loop control: builds the governing predicate
    for a strip-mined loop directly from scalar induction/limit, with the same
    wrap-around semantics as the sequential loop (saturating against overflow).
    """
    start = jnp.asarray(start)
    limit = jnp.asarray(limit)
    # Index dtype follows the promoted input dtype (int64 only materialises
    # under jax x64; weak Python ints promote to the default int32), so the
    # overflow check below runs in the same width as the caller's induction.
    idx_dtype = jnp.result_type(start.dtype, limit.dtype, jnp.int32)
    i = jnp.arange(vl, dtype=idx_dtype)
    # Saturate start + i instead of wrapping, mirroring the architected
    # "consistent with the sequential semantics" guarantee near INT_MAX.
    elem = start.astype(idx_dtype) + i
    wrapped = elem < start.astype(idx_dtype)        # overflow detection
    return jnp.where(wrapped, False, elem < limit.astype(idx_dtype))


def whilelo(start, limit, vl: int) -> Array:
    """Unsigned variant of ``whilelt``."""
    i = jnp.arange(vl, dtype=jnp.uint32)
    s = jnp.asarray(start).astype(jnp.uint32)
    lim = jnp.asarray(limit).astype(jnp.uint32)
    elem = s + i
    wrapped = elem < s
    return jnp.where(wrapped, False, elem < lim)


def index_pred(lengths: Array, vl: int) -> Array:
    """Batched whilelt: row r active for i < lengths[r].  Shape (*lengths, vl).

    This is the ragged-batch predicate used throughout the framework (variable
    sequence lengths without padding waste).
    """
    i = jnp.arange(vl, dtype=jnp.int32)
    return i[None, :] < lengths[..., None].astype(jnp.int32)


# --------------------------------------------------------------------------
# NZCV condition analogues (paper Table 1)
# --------------------------------------------------------------------------

def first(p: Array) -> Array:
    """N flag — set if the first element is active (``b.first`` continues loop)."""
    return p[..., 0].astype(jnp.bool_)


def none(p: Array) -> Array:
    """Z flag — set if no element is active."""
    return ~jnp.any(p, axis=-1)


def any_(p: Array) -> Array:
    return jnp.any(p, axis=-1)


def last(p: Array) -> Array:
    """!C flag — set if the LAST element is active (``b.last`` continues loop)."""
    return p[..., -1].astype(jnp.bool_)


def not_last(p: Array) -> Array:
    """C flag — set if the last element is NOT active."""
    return ~last(p)


# --------------------------------------------------------------------------
# Predicate queries / manipulation
# --------------------------------------------------------------------------

def cntp(p: Array, axis: int = -1) -> Array:
    """Count active elements (SVE ``cntp``) — drives ``incp`` induction updates."""
    return jnp.sum(p.astype(jnp.int32), axis=axis)


def pfirst(p: Array) -> Array:
    """Predicate selecting only the first active element (SVE ``pfirst``)."""
    idx = jnp.argmax(p, axis=-1)
    has = jnp.any(p, axis=-1)
    vl = p.shape[-1]
    onehot = jax.nn.one_hot(idx, vl, dtype=jnp.bool_)
    return onehot & has[..., None]


def plast(p: Array) -> Array:
    """Predicate selecting only the last active element."""
    return jnp.flip(pfirst(jnp.flip(p, axis=-1)), axis=-1)


def pnext(p_gov: Array, p_cur: Array) -> Array:
    """Next active element of ``p_gov`` strictly after the one in ``p_cur``.

    SVE ``pnext``: with p_cur = pfalse it yields the first active element.
    Returns an all-false predicate when exhausted (the ``last`` condition of the
    result is then false, terminating ``b.tcont``-style loops).
    """
    vl = p_gov.shape[-1]
    i = jnp.arange(vl, dtype=jnp.int32)
    # position of the element selected in p_cur (or -1 when p_cur is empty)
    cur_idx = jnp.where(jnp.any(p_cur, axis=-1), jnp.argmax(p_cur, axis=-1), -1)
    after = i > cur_idx[..., None]
    return pfirst(p_gov & after)


def propagate_last(p: Array) -> Array:
    """Monotone closure: active up to the LAST active element (inclusive)."""
    return jnp.flip(jnp.cumsum(jnp.flip(p, axis=-1), axis=-1) > 0, axis=-1)


def lane_iota(vl: int, dtype=jnp.int32) -> Array:
    """SVE ``index`` — the [0, 1, .. VL-1] induction vector, VL-agnostic."""
    return jnp.arange(vl, dtype=dtype)


def sel(p: Array, a: Array, b: Array) -> Array:
    """Predicated select (merging move): p ? a : b, broadcasting p on the left."""
    return jnp.where(_bcast(p, a.ndim), a, b)


def zeroing(p: Array, a: Array) -> Array:
    """Zeroing predication: inactive lanes read as 0 (SVE ``/z``)."""
    return jnp.where(_bcast(p, a.ndim), a, jnp.zeros_like(a))


def merging(p: Array, new: Array, old: Array) -> Array:
    """Merging predication: inactive lanes keep the old value (SVE ``/m``)."""
    return jnp.where(_bcast(p, new.ndim), new, old)


def cpy(p_lane: Array, scalar, vec: Array) -> Array:
    """Insert ``scalar`` into ``vec`` at the lanes of ``p_lane`` (SVE ``cpy /m``)."""
    return jnp.where(_bcast(p_lane, vec.ndim), jnp.asarray(scalar, vec.dtype), vec)


def ctermeq(a, b, p_last: Array):
    """SVE ``ctermeq`` loop-termination test used by scalarized sub-loops.

    Returns ``tcont``: True when the serial sub-loop should CONTINUE, i.e. the
    scalar values differ (no termination) AND the current lane predicate still
    has a next element (its ``last`` condition).  See paper Fig. 6c.
    """
    term = jnp.asarray(a) == jnp.asarray(b)
    return (~term) & jnp.any(p_last, axis=-1)


def _bcast(p: Array, ndim: int) -> Array:
    """Right-align a predicate against an ndim-array (lane axis is trailing)."""
    while p.ndim < ndim:
        p = p[None, ...]
    return p
