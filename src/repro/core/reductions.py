"""Horizontal operations (SVE C7): predicated reductions incl. ordered fadda.

SVE's horizontal ops resolve loop-carried dependencies that block SIMD
vectorization; ``fadda`` is the strictly-ordered FP add reduction that lets a
compiler vectorize loops where FP association order is semantically load-
bearing (paper §2.4, §3.3).  We provide:

  * predicated tree reductions (fast path; order-free),
  * ``fadda`` — strictly sequential, bit-identical to the scalar loop,
  * pairwise ("VL-agnostic deterministic") reduction: a fixed-shape reduction
    tree whose result is independent of how work is tiled — the compromise a
    VLA system needs so results do not change across vector lengths.

Cluster-scale ordered reduction (deterministic gradient all-reduce) lives in
``repro.dist.collectives`` and reuses the same algebra over devices.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import predicate as P

Array = jax.Array


def _masked(p: Array | None, x: Array, ident) -> Array:
    if p is None:
        return x
    return jnp.where(P._bcast(p, x.ndim), x, jnp.asarray(ident, x.dtype))


# ---- order-free predicated reductions (SVE faddv/eorv/orv/andv/smaxv/...) ----

def faddv(p, x, axis=-1):
    return jnp.sum(_masked(p, x, 0), axis=axis)


def eorv(p, x, axis=-1):
    ix = _masked(p, x, 0)
    return jax.lax.reduce(ix, jnp.asarray(0, ix.dtype),
                          jax.lax.bitwise_xor, dimensions=(ix.ndim + axis if axis < 0 else axis,))


def orv(p, x, axis=-1):
    return jnp.bitwise_or.reduce(_masked(p, x, 0), axis=axis)


def andv(p, x, axis=-1):
    return jnp.bitwise_and.reduce(_masked(p, x, -1), axis=axis)


def smaxv(p, x, axis=-1):
    return jnp.max(_masked(p, x, jnp.finfo(x.dtype).min
                           if jnp.issubdtype(x.dtype, jnp.floating)
                           else jnp.iinfo(x.dtype).min), axis=axis)


def sminv(p, x, axis=-1):
    return jnp.min(_masked(p, x, jnp.finfo(x.dtype).max
                           if jnp.issubdtype(x.dtype, jnp.floating)
                           else jnp.iinfo(x.dtype).max), axis=axis)


# ---- strictly-ordered reduction ----

def _ordered_scan(p, x, init, axis, partials: bool):
    """The one strictly-ordered accumulation core shared by ``fadda`` and
    ``fadda_scan`` (a single definition of the accumulation order; the
    reduction form carries only the scalar accumulator, no O(N) partials
    buffer).  Returns lax.scan's (final_acc, stacked_partials_or_None)."""
    if axis != -1:
        x = jnp.moveaxis(x, axis, -1)
        if p is not None and p.ndim == x.ndim:
            p = jnp.moveaxis(p, axis, -1)
    xm = jnp.moveaxis(_masked(p, x, 0), -1, 0)      # scan over the lane axis

    def step(acc, v):
        acc = acc + v
        return acc, (acc if partials else None)

    init_arr = jnp.broadcast_to(jnp.asarray(init, x.dtype), xm.shape[1:])
    return jax.lax.scan(step, init_arr, xm)


def fadda(p, x, init=0.0, axis=-1):
    """Strictly-ordered FP add reduction (SVE ``fadda``).

    Accumulates active elements in ascending element order into ``init``.
    Bit-identical to the sequential scalar loop — vectorizing a reduction with
    ``fadda`` never changes results across vector lengths (paper §3.3).
    Implemented as lax.scan (serial, like the hardware instruction whose cost
    is proportional to VL).
    """
    acc, _ = _ordered_scan(p, x, init, axis, partials=False)
    return acc


def fadda_scan(p, x, init=0.0, axis=-1):
    """All partial accumulations of ``fadda``: the inclusive ordered prefix
    sums, in ascending element order.

    ``fadda_scan(p, x)[..., i]`` is exactly the accumulator value after the
    hardware ``fadda`` has consumed elements 0..i — bit-identical to the
    sequential scalar loop, so a threshold test against it (e.g. the nucleus
    cutoff of top-p sampling) is deterministic across vector lengths and
    backends, unlike ``jnp.cumsum`` whose FP association order is
    implementation-defined.  Inactive lanes contribute 0 and repeat the
    running accumulator.
    """
    _, partials = _ordered_scan(p, x, init, axis, partials=True)
    out = jnp.moveaxis(partials, 0, -1)
    if axis != -1:
        out = jnp.moveaxis(out, -1, axis)
    return out


def fadda_tiled(p, x, init=0.0, vl: int = 128):
    """fadda over a long vector in VL-wide tiles: tiles are reduced
    sequentially, lanes within a tile sequentially — the exact order of the
    scalar loop, but expressed in the strip-mined form a VLA kernel uses.
    Equivalent to ``fadda`` for any vl; exists to prove VL-invariance."""
    n = x.shape[-1]
    pad = (-n) % vl
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
        pp = P.whilelt(0, n, n + pad) if p is None else (
            jnp.pad(p, [(0, 0)] * (p.ndim - 1) + [(0, pad)]))
    else:
        pp = P.ptrue(n) if p is None else p
    xt = x.reshape(x.shape[:-1] + (-1, vl))
    pt = jnp.broadcast_to(pp, x.shape).reshape(xt.shape)

    def tile_step(acc, tv):
        txs, tps = tv
        return fadda(tps, txs, init=acc), None

    acc, _ = jax.lax.scan(tile_step,
                          jnp.broadcast_to(jnp.asarray(init, x.dtype), x.shape[:-1]),
                          (jnp.moveaxis(xt, -2, 0), jnp.moveaxis(pt, -2, 0)))
    return acc


def pairwise_sum(x: Array, axis: int = -1) -> Array:
    """Fixed-topology pairwise reduction: deterministic and VL-independent
    (the practical middle ground between tree-sum speed and fadda ordering).
    Pads to a power of two with zeros; the reduction tree is a function of the
    padded length only, never of the tiling."""
    x = jnp.moveaxis(x, axis, -1)
    n = x.shape[-1]
    pot = 1 << (max(n - 1, 0)).bit_length() if n > 1 else 1
    if pot != n:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pot - n)])
    while x.shape[-1] > 1:
        x = x[..., 0::2] + x[..., 1::2]
    return x[..., 0]
