"""Vector-length agnosticism (SVE C1) at the lane/tile scale.

SVE lets one binary run at any hardware vector length VL in {128..2048} bits by
making VL an implicit operand (``incd``, ``whilelt``, ``cntd``).  The TPU
analogue: kernels are written against a *symbolic* VL (a block/tile width)
chosen at trace time from the dtype and the VMEM budget, and every loop bound /
tail is handled by predication rather than shape specialization.  One kernel
source therefore serves every shape — the software never hard-codes the width.

TPU native tile geometry (v4/v5): the VPU operates on (sublane, lane) =
(8, 128) float32 registers; narrower dtypes pack more sublanes.  The MXU is a
128x128 systolic array.  "VL" for a TPU kernel is the lane-dim block width,
always a multiple of 128, with the sublane dim a multiple of the dtype packing.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp

# Architectural constants of the target (TPU v5e, per the roofline spec).
LANE = 128                     # lanes per VREG row / MXU edge
_SUBLANE_BY_ITEMSIZE = {4: 8, 2: 16, 1: 32}
VMEM_BYTES = 16 * 1024 * 1024  # ~16 MiB VMEM per core
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link

# SVE architectural VL range, expressed in lanes-of-f32 for the Fig.8 analogue
# benchmarks (128-bit .. 2048-bit vectors = 4 .. 64 f32 lanes).
SVE_MIN_BITS = 128
SVE_MAX_BITS = 2048


def sublanes(dtype) -> int:
    """Sublane packing for a dtype — rows of a native VREG tile."""
    itemsize = jnp.dtype(dtype).itemsize
    try:
        return _SUBLANE_BY_ITEMSIZE[itemsize]
    except KeyError as e:
        raise ValueError(f"unsupported itemsize {itemsize} for dtype {dtype}") from e


def native_tile(dtype) -> tuple[int, int]:
    """The minimal hardware tile (sublane, lane) for ``dtype``."""
    return (sublanes(dtype), LANE)


def round_up(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def num_tiles(n: int, vl: int) -> int:
    """How many VL-wide tiles cover n elements (the ``incd``/loop-trip count)."""
    return cdiv(n, vl)


def pad_to_vl(n: int, vl: int) -> int:
    return round_up(n, vl)


@dataclasses.dataclass(frozen=True)
class VL:
    """A symbolic vector length: block shape chosen at trace time.

    Mirrors SVE's implicit-VL model: user code asks for a VL suited to the
    problem and hardware; the *same* calling code works for any choice.
    """

    block: int                 # lane-dim width (multiple of LANE)
    dtype: jnp.dtype = jnp.dtype(jnp.float32)

    def __post_init__(self):
        if self.block % LANE != 0:
            raise ValueError(f"VL block {self.block} not a multiple of lane width {LANE}")

    @property
    def bits(self) -> int:
        return self.block * jnp.dtype(self.dtype).itemsize * 8

    def tiles(self, n: int) -> int:
        return num_tiles(n, self.block)

    def padded(self, n: int) -> int:
        return pad_to_vl(n, self.block)


def choose_vl(
    n: int,
    dtype=jnp.float32,
    *,
    operands: int = 2,
    vmem_budget: int = VMEM_BYTES // 2,
    max_block: int = 4096,
) -> VL:
    """Pick a block width for an n-element axis.

    Policy (the 'implementation choice' SVE grants hardware designers, made at
    trace time instead): largest MXU-aligned block such that ``operands``
    blocks fit the VMEM budget, capped by the problem size and ``max_block``.
    """
    itemsize = jnp.dtype(dtype).itemsize
    by_budget = vmem_budget // max(1, operands * itemsize * sublanes(dtype))
    block = min(max_block, by_budget, pad_to_vl(max(n, 1), LANE))
    block = max(LANE, (block // LANE) * LANE)
    return VL(block=block, dtype=jnp.dtype(dtype))


def sve_vl_sweep(dtype=jnp.float32, bits: Sequence[int] = (128, 256, 512)) -> list[VL]:
    """VLs matching the paper's Fig. 8 sweep (128/256/512-bit vectors).

    On TPU the minimum lane-dim block is 128 *elements*, so we express the
    paper's relative sweep as multiples of the native tile: a 2x-bit VL is a
    2x-wider block.  (128-bit SVE : 512-bit SVE) :: (128-lane : 512-lane).
    """
    return [VL(block=LANE * (b // SVE_MIN_BITS), dtype=jnp.dtype(dtype)) for b in bits]
