from .pipeline import SyntheticLM, make_batches, pack_documents  # noqa: F401
