"""Data pipeline: deterministic synthetic LM stream + ragged document packing.

Production properties kept honest at container scale:
  * host-sharded: each data-parallel host materializes only its shard
    (``shard_index`` / ``shard_count``);
  * stateless & restartable: batch t is a pure function of (seed, t) — after
    a fault-tolerance restore the stream resumes exactly (no iterator state
    in checkpoints);
  * double-buffered prefetch (background thread) hides host latency;
  * ragged packing with whilelt predicates instead of padding waste —
    documents shorter than seq_len yield per-row ``lens`` consumed by the
    predicated attention masks (the paper's C2/C3 applied to the input path).
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class SyntheticLM:
    """Deterministic synthetic token stream with document structure.

    Documents have power-law lengths; tokens follow a mixed unigram process
    seeded per (seed, doc_id) so any shard/step is reproducible in isolation.
    """

    def __init__(self, vocab_size: int, seq_len: int, *, seed: int = 0,
                 mean_doc_len: int = 512):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.seed = seed
        self.mean_doc_len = mean_doc_len

    def batch(self, step: int, batch_size: int, *, shard_index: int = 0,
              shard_count: int = 1):
        """(tokens, labels, lens) for global step ``step``, host shard only."""
        assert batch_size % shard_count == 0
        local = batch_size // shard_count
        rows = np.arange(local) + shard_index * local + step * batch_size
        rng = np.random.Generator(np.random.Philox(key=self.seed, counter=rows[0]))
        toks = np.empty((local, self.seq_len + 1), np.int32)
        lens = np.empty((local,), np.int32)
        for i, row in enumerate(rows):
            r = np.random.Generator(np.random.Philox(key=self.seed, counter=row))
            ln = int(np.clip(r.geometric(1.0 / self.mean_doc_len),
                             8, self.seq_len))
            # token process: unigram with a row-specific hot region (learnable)
            base = r.integers(0, self.vocab_size, size=self.seq_len + 1)
            hot = r.integers(0, max(self.vocab_size // 16, 1))
            mask = r.random(self.seq_len + 1) < 0.7
            toks[i] = np.where(mask, hot + (base % 7), base).astype(np.int32)
            toks[i] %= self.vocab_size
            toks[i, ln:] = 0
            lens[i] = ln
        tokens = toks[:, :-1]
        labels = toks[:, 1:].copy()
        # predicated loss: ignore positions at/after each row's length
        cols = np.arange(self.seq_len)[None, :]
        labels[cols >= (lens[:, None] - 1)] = -1
        return tokens, labels, lens


def pack_documents(docs: list[np.ndarray], seq_len: int, pad_id: int = 0):
    """Greedy ragged packing: concatenate docs into rows of <= seq_len.

    Returns (tokens (N, seq_len), lens (N,)): the tail of each row past
    ``lens`` is inert under the whilelt predicates downstream.
    """
    rows, lens = [], []
    cur: list[int] = []
    for d in docs:
        d = list(int(x) for x in d)
        while d:
            space = seq_len - len(cur)
            take = d[:space]
            cur.extend(take)
            d = d[space:]
            if len(cur) == seq_len:
                rows.append(cur)
                lens.append(seq_len)
                cur = []
    if cur:
        lens.append(len(cur))
        rows.append(cur + [pad_id] * (seq_len - len(cur)))
    return (np.asarray(rows, np.int32),
            np.asarray(lens, np.int32))


def make_batches(source: SyntheticLM, batch_size: int, *, start_step: int = 0,
                 shard_index: int = 0, shard_count: int = 1,
                 prefetch: int = 2, stop_step: Optional[int] = None) -> Iterator:
    """Double-buffered batch iterator (background producer thread)."""
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set() and (stop_step is None or step < stop_step):
            q.put((step, source.batch(step, batch_size,
                                      shard_index=shard_index,
                                      shard_count=shard_count)))
            step += 1
        q.put(None)

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    try:
        while True:
            item = q.get()
            if item is None:
                return
            yield item
    finally:
        stop.set()
