"""repro.dist — cluster-scale VLA: the paper's vector-length-agnostic
contract lifted from lanes to chips.  Logical axis names resolve onto
whatever mesh is present (``sharding``), and horizontal reductions become
deterministic cross-device collectives (``collectives``).
"""

from . import collectives, serve, sharding  # noqa: F401

__all__ = ["sharding", "collectives", "serve"]
