"""Deterministic cross-device reductions — the paper's horizontal-operation
orderings (§2.3.6) at chip scale.

SVE exposes BOTH a strictly-ordered floating-point reduction (``fadda``) and
a pairwise-tree one (``faddv``); the same two orderings reappear here as
collectives, plus an int8 error-feedback compressed variant for gradient
traffic.  All three are shard_map-level primitives: they take the local shard
and an axis name.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: reduction orderings selectable at launch: "fast" is the backend's native
#: all-reduce (scheduling-dependent association), "ordered"/"pairwise" are
#: the fadda/faddv orderings below.
PSUM_MODES = ("fast", "ordered", "pairwise")

_PSUM_MODE = "fast"

# observability hook (repro.obs): when set, each TRACED ``psum`` call bumps
# a ``psum_<mode>_traced`` counter — a trace-time census of which ordering
# the compiled programs bake in (NOT a runtime collective count; jit caching
# means a cached executable re-runs without re-tracing).  Wire from
# ``launch/serve.py --metrics`` via ``set_obs``.
_OBS = None


def set_obs(obs) -> None:
    """Attach an ``repro.obs.Obs`` whose registry counts traced psum calls
    by mode (None detaches)."""
    global _OBS
    _OBS = obs


def set_psum_mode(mode: str) -> None:
    """Select the ordering ``psum`` dispatches to (process-wide choice point;
    wire from ``launch/serve.py --psum``).  Call before tracing."""
    if mode not in PSUM_MODES:
        raise ValueError(f"psum mode {mode!r} not in {PSUM_MODES}")
    global _PSUM_MODE
    _PSUM_MODE = mode


def psum_mode() -> str:
    return _PSUM_MODE


def psum(x, axis_name: str, mode: str | None = None):
    """The serve-path reduction choice point: one name model code can call,
    resolving to the native all-reduce or a deterministic ordering.  Each
    call is wrapped in a ``psum_<mode>`` named_scope so HLO dumps and XLA
    profiles attribute collective cost to the ordering that produced it."""
    mode = _PSUM_MODE if mode is None else mode
    if _OBS is not None:
        _OBS.metrics.inc(f"psum_{mode}_traced")
    with jax.named_scope(f"psum_{mode}"):
        if mode == "ordered":
            return ordered_psum(x, axis_name)
        if mode == "pairwise":
            return pairwise_psum(x, axis_name)
        return jax.lax.psum(x, axis_name)


def ordered_psum(x, axis_name: str):
    """Strictly-ordered sum over the mesh axis: bit-identical to a sequential
    left-to-right loop over shards (the cross-device ``fadda``).

    Costs an all-gather instead of an all-reduce — ordering is bought with
    bandwidth, exactly the fadda/faddv trade of the paper.
    """
    xs = jax.lax.all_gather(x, axis_name)          # (N, ...) identical everywhere
    n = xs.shape[0]

    def body(i, acc):
        return acc + xs[i]

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(xs[0]))


def pairwise_psum(x, axis_name: str):
    """Deterministic pairwise-tree sum (the cross-device ``faddv``): fixed
    balanced-tree association independent of scheduling, error O(log N)."""
    xs = jax.lax.all_gather(x, axis_name)
    while xs.shape[0] > 1:
        n = xs.shape[0]
        half = n // 2
        paired = xs[: 2 * half].reshape((half, 2) + xs.shape[1:]).sum(axis=1)
        if n % 2:
            paired = jnp.concatenate([paired, xs[-1:]], axis=0)
        xs = paired
    return xs[0]


def compressed_psum(g, axis_name: str, err):
    """int8-quantized mean with per-shard error feedback.

    Each shard quantizes (g + err) to int8 against its own absmax scale; the
    quantization residual is carried into the next round, so the accumulated
    mean over repeated rounds converges to the exact mean (the residual
    telescopes).  Returns (mean, new_err).
    """
    comp = g + err
    scale = jnp.maximum(jnp.max(jnp.abs(comp)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(comp / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_err = comp - deq
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    mean = jax.lax.psum(deq, axis_name) / n
    return mean, new_err
