"""Serve-state placement: resolve the scheduler's device state onto a mesh.

The serving analogue of the paper's one-VL-agnostic-binary promise: ONE
serve program whose state placement — KV page pools over the ``model``
axis's KV-head shards, request lanes over the ``data`` axis — resolves
through the same logical-axis rule table (``dist.sharding.spec_for``) on
whatever mesh exists.  Model code stays mesh-free; the engine commits its
inputs here and GSPMD propagates the layout through the fused step.

Layout contract (all via ``SERVE_RULES`` — no FSDP weight split while
serving, the data axis carries lanes only):

  * page pools ``<key>_pages`` — ``lead + (P, Hkv, page_size, D)``: KV
    heads take "model" ("kv_heads" rule).  A pool whose head count does
    not divide the axis REPLICATES (the divisibility fallback); the page
    and page-size dims are never sharded — pages are gathered by table,
    splitting them would turn every gather into a collective.
  * scale pools ``<key>_pages_scale`` — ``lead + (P, Hkv, page_size)``
    (quantized caches): KV heads take "model" with their pool; page dims
    whole, for the same reason.
  * per-lane dense KV — ``lead + (B, Hkv, S, D)``: lanes over "data", KV
    heads over "model" with the ``kv_seq`` flash-decode fallback for GQA
    head counts (left-to-right resolution in ``spec_for``).
  * page tables / conv taps / SSM states / sampler lanes / out_buf /
    per-lane scalars: lanes over "data" only.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding

from . import sharding as SH

#: per-lane KV arrays end in (B, Hkv, S, D): rank past the lane axis
_KV_TAIL_RANK = 4


def cache_axes(cfg, cache) -> dict:
    """Logical-axes tuples for every key of a serve cache (dense or paged).

    Derives the lane axis from the family's ``cache_batch_axes`` contract
    and the KV-vs-state split from key names — the serve-side mirror of
    ``models`` layouts, kept here so model code never sees a mesh.
    """
    from repro.models import get_model  # lazy: models imports repro.dist

    lane_ax = get_model(cfg).cache_batch_axes(cfg)
    out = {}
    for key, leaf in cache.items():
        nd = len(leaf.shape)
        if key == "page_table":
            out[key] = ("batch",) + (None,) * (nd - 1)
        elif key.endswith("_pages"):
            ax = [None] * nd
            ax[nd - 3] = "kv_heads"
            out[key] = tuple(ax)
        elif key.endswith("_pages_scale"):
            # per-slot scale pools lead + (P, Hkv, ps): shard the head axis
            # with the pool it scales; page dims stay whole
            ax = [None] * nd
            ax[nd - 2] = "kv_heads"
            out[key] = tuple(ax)
        elif key in lane_ax:
            la = lane_ax[key]
            ax = [None] * nd
            ax[la] = "batch"
            if (nd - la == _KV_TAIL_RANK and "conv" not in key
                    and "state" not in key):
                ax[la + 1] = "act_kv_heads"
                ax[la + 2] = "kv_seq"
            out[key] = tuple(ax)
        else:
            out[key] = (None,) * nd
    return out


def cache_shardings(cfg, cache, mesh, rules: Optional[dict] = None) -> dict:
    rules = SH.SERVE_RULES if rules is None else rules
    return SH.tree_shardings(cache, cache_axes(cfg, cache), mesh, rules)


def lane_shardings(tree, mesh, rules: Optional[dict] = None):
    """Shardings for any pytree of lane-leading arrays (out_buf, tok,
    sampler state, ...): "batch" on dim 0, rest replicated."""
    rules = SH.SERVE_RULES if rules is None else rules
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, SH.spec_for(
            leaf.shape, ("batch",) + (None,) * (len(leaf.shape) - 1),
            mesh, rules)),
        tree)


def shard_params(model, cfg, params, mesh, rules: Optional[dict] = None):
    """Commit params to their TP placement per the family's logical-axes
    tree (heads/mlp/experts/vocab over "model"; under SERVE_RULES nothing
    rides the data axis)."""
    rules = SH.SERVE_RULES if rules is None else rules
    return jax.device_put(
        params, SH.tree_shardings(params, model.axes(cfg), mesh, rules))


def constrain_cache(cfg, cache) -> dict:
    """Sharding-constrain a cache built INSIDE a jitted trace (the fused
    step's admission sub-caches): without the hint GSPMD may materialise
    the fresh zeros replicated and reshard on the first write.  Identity
    when no ambient mesh rules are active."""
    if not SH.rules_active():
        return cache
    axes = cache_axes(cfg, cache)
    return {k: SH.constrain(v, axes[k]) for k, v in cache.items()}
