"""Mesh-agnostic sharding resolution (cluster-scale VLA, DESIGN.md §2).

Model code annotates every array dim with a LOGICAL axis name ("embed",
"heads", "batch", ...) and never mentions a mesh.  At jit boundaries the rule
table below resolves each logical name onto the mesh axes that happen to
exist, with the same discipline SVE applies to vector lanes:

  * **divisibility fallback** — a dim that doesn't divide the mesh axis size
    replicates instead of erroring (the VL-agnostic "partial last strip").
  * **no axis reuse** — one mesh axis shards at most one dim per array,
    resolved left to right.
  * **folding** — "batch" folds all pure-DP axes present ("pod" x "data").
  * **flash-decode fallback** — when kv_heads can't take the "model" axis
    (GQA with few KV heads), the kv_seq dim takes it instead, which is
    exactly the flash-decode split-K layout.

The same logical tree therefore lowers onto a laptop CPU, one pod, or a
multi-pod mesh without touching model code.
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# logical axis -> ordered tuple of mesh axes it may occupy (folded jointly
# when more than one is present).  Missing mesh axes are simply skipped.
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "act_seq": ("model",),          # Megatron-SP residual split
    "act_heads": ("model",),
    "act_kv_heads": ("model",),
    "act_experts": ("model",),      # MoE dispatch/combine expert dim
    "act_mlp": ("model",),          # MLP intermediate stays sharded between
                                    # up-proj and down-proj (Megatron TP pair)
    "act_vocab": ("model",),        # logits leave the unembed dot vocab-
                                    # sharded; sampling gathers the (tiny)
                                    # logit row, never the head weight
    "kv_seq": ("model",),           # flash-decode fallback target
    "embed": ("data",),             # FSDP-ish weight split
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    # contraction-feeding weight dims (attention wo, MLP w_down) and the
    # out-proj input: Megatron row-parallel in training — a partial dot per
    # shard, psum after.  Serving overrides these (see SERVE_RULES).
    "heads_in": ("model",),
    "mlp_in": ("model",),
    "act_attn_in": ("model",),
    "act_mlp_in": ("model",),
    "act_experts_in": ("model",),   # MoE dispatch-gather output
    "act_experts_out": ("model",),  # MoE expert outputs entering combine
    "vocab": ("model",),
    "experts": ("model",),
    "layers": (),                   # scanned axis: never sharded
}

# Serving variant: decode reads every weight every step, so an FSDP-style
# "embed" split over the data axis would all-gather the full parameter set
# per layer per token — during serving the data axis carries request LANES
# only.  act_seq likewise stays whole (decode sequence length is 1; prefill
# chunks are short and batch-sharded already).
#
# heads_in / mlp_in / act_attn_in / act_mlp_in replicate: served tokens must
# be BYTE-identical to the 1-device engine, and a Megatron row-parallel dot
# (split contraction + psum) reassociates the f32 sum — ulp-level logit
# noise that top-p's sort order then amplifies into a different sampled
# token.  Serving therefore keeps only COLUMN-parallel weights sharded
# (qkv / mlp-up / unembed: contraction dim whole, bitwise per element),
# replicates the row-parallel weights, and all-gathers the small
# activations (merged attn heads, MLP intermediate) right before their
# dots — every contraction runs whole, so logits are bitwise-identical to
# the unsharded engine BY CONSTRUCTION, and the per-step collectives are a
# few KB of activations instead of per-layer reductions.  act_mlp itself
# stays SHARDED so the up/gate dot outputs land sharded (otherwise GSPMD
# would all-gather the up-proj weights to produce a replicated output);
# only the act_mlp_in constraint on the down-proj input gathers.
SERVE_RULES = dict(DEFAULT_RULES, embed=(), act_seq=(),
                   heads_in=(), mlp_in=(), act_attn_in=(), act_mlp_in=(),
                   act_experts_in=(), act_experts_out=())


def _candidates(name: str, mesh, rules) -> list[tuple[str, ...]]:
    """Orderings to try for one logical name: the full folded tuple of
    present mesh axes first, then each single axis."""
    want = rules.get(name, ())
    present = tuple(a for a in want if a in mesh.axis_names)
    if not present:
        return []
    cands = [present]
    if len(present) > 1:
        cands += [(a,) for a in present]
    return cands


def spec_for(shape, axes, mesh, rules: Optional[dict] = None) -> P:
    """Resolve one array's logical axes tuple to a PartitionSpec on ``mesh``.

    ``axes``: tuple of logical names (or None) matching ``shape``'s rank, or
    None for a fully replicated array.
    """
    if axes is None:
        return P()
    rules = DEFAULT_RULES if rules is None else rules
    used: set[str] = set()
    entries = []
    for dim, name in zip(shape, axes):
        placed = None
        if name is not None:
            for cand in _candidates(name, mesh, rules):
                free = tuple(a for a in cand if a not in used)
                if len(free) != len(cand):
                    continue                      # no mesh-axis reuse
                size = 1
                for a in free:
                    size *= mesh.shape[a]
                if size > 1 and dim % size == 0:  # divisibility fallback
                    placed = free
                    break
            if placed is not None:
                used.update(placed)
        entries.append(placed[0] if placed is not None and len(placed) == 1
                       else placed)
    return P(*entries)


def tree_shardings(tree, axes_tree, mesh, rules: Optional[dict] = None):
    """NamedSharding tree for a pytree of arrays/ShapeDtypeStructs given the
    matching tree of logical-axes tuples (tuples are leaves of axes_tree)."""
    return jax.tree.map(
        lambda leaf, ax: NamedSharding(mesh, spec_for(leaf.shape, ax, mesh,
                                                      rules)),
        tree, axes_tree)


def batch_axes_for(batch):
    """Logical axes for an input batch dict: leading dim is the request/lane
    axis, everything else replicated."""
    return jax.tree.map(
        lambda leaf: ("batch",) + (None,) * (len(leaf.shape) - 1), batch)


def cache_axes_for(cache):
    """Logical axes for a decode-cache dict (see models.cache_batch_axes for
    the authoritative per-family lane axis; this mirrors those layouts)."""
    out = {}
    for key, leaf in cache.items():
        nd = len(leaf.shape)
        if nd == 1:
            out[key] = ("batch",)
        elif "conv" in key:                        # (..., B, W, D)
            ax = [None] * nd
            ax[nd - 3] = "batch"
            out[key] = tuple(ax)
        elif "state" in key:                       # (..., B, H, hd, state)
            ax = [None] * nd
            ax[nd - 4] = "batch"
            out[key] = tuple(ax)
        else:                                      # KV: (..., B, Hkv, S, D)
            ax = [None] * nd
            ax[nd - 4] = "batch"
            ax[nd - 3] = "act_kv_heads"
            ax[nd - 2] = "kv_seq"
            out[key] = tuple(ax)
    return out


# ---------------------------------------------------------------------------
# Ambient mesh for activation constraints (opt-in, no-op otherwise)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[tuple] = None


@contextlib.contextmanager
def use_mesh_rules(mesh, rules: Optional[dict] = None):
    """Within this context, ``constrain`` resolves logical axes against
    ``mesh``; outside it, ``constrain`` is the identity."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, (mesh, DEFAULT_RULES if rules is None else rules)
    try:
        yield
    finally:
        _ACTIVE = prev


def rules_active() -> bool:
    """True inside a ``use_mesh_rules`` context (``constrain`` is live)."""
    return _ACTIVE is not None


def constrain(x, axes):
    """Activation sharding constraint under the ambient mesh (identity when
    no mesh rules are active — keeps single-host tests mesh-free)."""
    if _ACTIVE is None:
        return x
    mesh, rules = _ACTIVE
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(x.shape, axes, mesh, rules)))
