# Pallas TPU kernels for the compute hot-spots of the framework.
# Each subpackage ships: kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
# ops.py (jit'd public wrapper), ref.py (pure-jnp oracle used by tests).
#
# All kernels follow the paper's predication discipline: ragged tails and
# data-dependent masks are handled by whilelt-style predicates computed
# inside the kernel, never by shape-specialized variants (SVE C1-C3).
