from .ops import daxpy  # noqa: F401
