"""Daxpy — the paper's Fig. 2 kernel, as a predicated VLA Pallas kernel.

``y[i] = a*x[i] + y[i]`` for i < n, where n need not divide the block size.
The tail is handled exactly the way SVE's ``whilelt`` handles it: the kernel
computes the governing predicate from the scalar bound and merges (``/m``)
only the active lanes — one kernel source for every (n, VL) combination.

TPU mapping: VL = block elements (sublane x lane tile); the grid strip-mines
the array; `i` below is the induction variable the `incd` of Fig. 2c advances.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _daxpy_kernel(n_ref, a_ref, x_ref, y_ref, o_ref, *, block: int):
    pid = pl.program_id(0)
    # whilelt(i, n): governing predicate for this strip of the loop
    i = pid * block + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    p = i < n_ref[0]
    a = a_ref[0]
    fused = a * x_ref[...] + y_ref[...]          # fmla z2, p0/m, z1, z0
    o_ref[...] = jnp.where(p, fused, y_ref[...])  # /m merging predication


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def daxpy_pallas(x, y, a, n, *, block: int = 1024, interpret: bool = True):
    """x, y: (padded_len,) arrays; a: scalar; n: active element count."""
    padded = x.shape[0]
    assert padded % block == 0, (padded, block)
    grid = (padded // block,)
    kernel = functools.partial(_daxpy_kernel, block=block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),           # n (scalar prefetch-ish)
            pl.BlockSpec(memory_space=pl.ANY),           # a
            pl.BlockSpec((1, block), lambda i: (0, i)),  # x strip in VMEM
            pl.BlockSpec((1, block), lambda i: (0, i)),  # y strip in VMEM
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, padded), x.dtype),
        interpret=interpret,
    )(
        jnp.asarray([n], jnp.int32),
        jnp.asarray([a], x.dtype),
        x.reshape(1, padded),
        y.reshape(1, padded),
    ).reshape(padded)
