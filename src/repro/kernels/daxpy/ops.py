"""Public daxpy op: VL-agnostic strip-mined call into the Pallas kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import vla

from .kernel import daxpy_pallas


def daxpy(x, y, a, n=None, *, block: int | None = None, interpret: bool = True):
    """Vector-length-agnostic daxpy: pads to the chosen VL, runs the
    predicated kernel, returns the first len(x) elements.  ``n`` defaults to
    the full length; any n <= len(x) exercises the predicated tail."""
    length = x.shape[0]
    n = length if n is None else n
    if block is None:
        block = vla.choose_vl(length, x.dtype, operands=3).block
    padded = vla.pad_to_vl(length, block)
    if padded != length:
        x = jnp.pad(x, (0, padded - length))
        y = jnp.pad(y, (0, padded - length))
    out = daxpy_pallas(x, y, a, n, block=block, interpret=interpret)
    return out[:length]
