"""Pure-jnp oracle for the daxpy kernel (paper Fig. 2a)."""

import jax.numpy as jnp


def daxpy_ref(x, y, a, n):
    """y[i] = a*x[i] + y[i] for i < n; elements at/after n are untouched."""
    i = jnp.arange(x.shape[0])
    return jnp.where(i < n, a * x + y, y)
