from .ops import fadda  # noqa: F401
