"""Strictly-ordered floating-point accumulation (SVE ``fadda``) for TPU.

The paper's §2.4/§3.3: vectorizing a reduction must not change FP results
when ordering is semantically load-bearing.  The hardware instruction is
serial with cost proportional to VL; this kernel mirrors that honestly — a
sequential fori_loop over lanes inside each VL tile, with the scalar
accumulator carried across tiles in SMEM.  It exists for *correctness-
critical* reductions (loss auditing, deterministic eval), not throughput;
``core.reductions.pairwise_sum`` is the fast deterministic alternative.

The governing predicate (whilelt against n) zeroes inactive lanes, so the
padded tail never perturbs the accumulation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fadda_kernel(n_ref, x_ref, o_ref, acc_scr, *, block: int, n_tiles: int):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        acc_scr[0, 0] = jnp.float32(0.0)

    i = pid * block + jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    p = i < n_ref[0]                                   # whilelt(i, n)
    xm = jnp.where(p, x_ref[...].astype(jnp.float32), 0.0)

    def body(j, acc):
        return acc + xm[0, j]                          # strict element order

    acc_scr[0, 0] = jax.lax.fori_loop(0, block, body, acc_scr[0, 0])

    @pl.when(pid == n_tiles - 1)
    def _emit():
        o_ref[0, 0] = acc_scr[0, 0]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fadda_pallas(x, n, *, block: int = 512, interpret: bool = True):
    padded = x.shape[0]
    assert padded % block == 0
    n_tiles = padded // block
    kernel = functools.partial(_fadda_kernel, block=block, n_tiles=n_tiles)
    out = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray([n], jnp.int32), x.reshape(1, padded))
    return out[0, 0]
