"""Public fadda op: VL-agnostic padding wrapper."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import vla

from .kernel import fadda_pallas


def fadda(x, n=None, *, block: int = 512, interpret: bool = True):
    """Strictly-ordered f32 accumulation of x[:n] (paper §2.4)."""
    length = x.shape[0]
    n = length if n is None else n
    padded = vla.pad_to_vl(length, block)
    if padded != length:
        x = jnp.pad(x, (0, padded - length))
    return fadda_pallas(x.astype(jnp.float32), n, block=block, interpret=interpret)
