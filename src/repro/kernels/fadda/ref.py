"""Oracle for the fadda kernel: the strictly-ordered scalar loop."""

import numpy as np


def fadda_ref(x, n=None, init=0.0):
    """Bit-exact sequential accumulation of x[:n] into init (float32)."""
    x = np.asarray(x, np.float32)
    n = x.shape[0] if n is None else n
    acc = np.float32(init)
    for v in x[:n]:
        acc = np.float32(acc + v)
    return acc
