from .ops import flash_attention  # noqa: F401
