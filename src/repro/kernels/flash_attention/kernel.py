"""Predicated flash attention for TPU (Pallas).

The SVE story (DESIGN.md C1-C3) at lane scale: ONE kernel source handles
causal, sliding-window, cross- and ragged-length attention.  Every variant is
a *predicate* built inside the kernel from scalar bounds (``whilelt`` algebra
over broadcasted iotas) — never a separate shape-specialized kernel.  Tails
(Sq or Skv not multiples of the block) are predicated, not padded-and-wasted.

Blocking: grid (B, Hq, Sq/bq, Skv/bk); the KV axis is the innermost,
sequential ("arbitrary") dimension with the online-softmax running state
(m, l, acc) carried in VMEM scratch.  BlockSpecs keep one (bq, D) query tile,
one (bk, D) key tile and one (bk, D) value tile resident; with bq=bk=512 and
D=128 in f32 that is ~1.3 MiB of operand VMEM plus the (bq, bk) logits tile —
comfortably inside the ~16 MiB v5e budget and MXU-aligned (multiples of 128).

GQA is handled in the K/V index_map (head h reads KV head h // group), so KV
tiles are fetched once per group from HBM's point of view after XLA CSE.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # finite stand-in: keeps exp/where NaN-free in f32


def _flash_kernel(
    # scalar-prefetch style operands (full arrays in ANY memory space)
    kvlen_ref, qoff_ref, win_ref,
    # blocked operands
    q_ref, k_ref, v_ref,
    # blocked output
    o_ref,
    # VMEM scratch (persistent across the sequential KV grid axis)
    m_scr, l_scr, acc_scr,
    *, bq: int, bk: int, n_kv: int, causal: bool, scale: float,
):
    _flash_tile(kvlen_ref, qoff_ref, win_ref, q_ref,
                k_ref[0, 0].astype(jnp.float32),
                v_ref[0, 0].astype(jnp.float32),
                o_ref, m_scr, l_scr, acc_scr,
                bq=bq, bk=bk, n_kv=n_kv, causal=causal, scale=scale)


def _flash_tile(
    kvlen_ref, qoff_ref, win_ref, q_ref,
    k, v,                                          # (bk, D) f32 tiles, loaded
    o_ref, m_scr, l_scr, acc_scr,
    *, bq: int, bk: int, n_kv: int, causal: bool, scale: float,
):
    """The shared online-softmax tile body.  K/V tiles arrive as loaded f32
    arrays so callers may widen narrow (quantized) storage on the way in —
    the in-register half of SVE's extending load — without forking the math.
    """
    b = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (bq, bk)

    # ---- the governing predicate (whilelt algebra; paper §2.3) ----
    qpos = (qoff_ref[b] + iq * bq
            + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    pred = kpos < kvlen_ref[b]                      # ragged KV tail: whilelt
    if causal:
        pred &= qpos >= kpos
    # dynamic sliding window (2**30 = "no window"): ONE kernel serves local
    # and global layers — the predicate, not the kernel, changes (SVE C2)
    pred &= kpos > (qpos - win_ref[0])

    s = jnp.where(pred, s, NEG_INF)

    m_prev = m_scr[:, :1]                           # (bq, 1)
    l_prev = l_scr[:, :1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)                 # <= 1; exp(-inf-(-inf)) avoided
    p = jnp.where(pred, jnp.exp(s - m_new), 0.0)    # zeroing predication
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)

    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = l_scr[:, :1]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)
        out = jnp.where(l > 0.0, out, 0.0)          # empty-predicate rows -> 0
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# Paged variant: the page table drives the K/V BlockSpec index_map
# ---------------------------------------------------------------------------
#
# With scalar prefetch (PrefetchScalarGridSpec) the page table is available to
# the index_map itself, so the pipeline fetches physical page
# ``table[b, j]`` when the grid asks for lane b's logical block j — the SVE
# gather-load contract expressed at the block-fetch level: the kernel body is
# UNCHANGED from the dense path (same predicate algebra, same online softmax),
# only the address stream indirects through the index vector.

def _flash_kernel_paged(
    # scalar-prefetch operands (SMEM)
    table_ref, kvlen_ref, qoff_ref, win_ref,
    # blocked operands
    q_ref, k_ref, v_ref,
    # blocked output
    o_ref,
    # VMEM scratch
    m_scr, l_scr, acc_scr,
    *, bq: int, page_size: int, n_pages: int, causal: bool, scale: float,
):
    del table_ref                                  # consumed by the index_maps
    _flash_kernel(kvlen_ref, qoff_ref, win_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, bq=bq, bk=page_size, n_kv=n_pages,
                  causal=causal, scale=scale)


def _flash_kernel_paged_quant(
    # scalar-prefetch operands (SMEM)
    table_ref, kvlen_ref, qoff_ref, win_ref,
    # blocked operands: narrow K/V page tiles + their per-slot scale rows
    q_ref, k_ref, v_ref, ks_ref, vs_ref,
    # blocked output
    o_ref,
    # VMEM scratch
    m_scr, l_scr, acc_scr,
    *, bq: int, page_size: int, n_pages: int, causal: bool, scale: float,
):
    """Quantized paged tile: the scale rows arrive through the SAME
    table-driven index_map as the K/V page, and the narrow elements widen in
    register (``q8 * scale`` per token row) before the unchanged softmax body
    — SVE §2.3.3's extending gather-load at the block-fetch level."""
    del table_ref                                  # consumed by the index_maps
    k = (k_ref[0, 0].astype(jnp.float32)
         * ks_ref[0, 0].astype(jnp.float32)[:, None])
    v = (v_ref[0, 0].astype(jnp.float32)
         * vs_ref[0, 0].astype(jnp.float32)[:, None])
    _flash_tile(kvlen_ref, qoff_ref, win_ref, q_ref, k, v, o_ref,
                m_scr, l_scr, acc_scr, bq=bq, bk=page_size, n_kv=n_pages,
                causal=causal, scale=scale)


@functools.partial(
    jax.jit,
    static_argnames=("bq", "causal", "scale", "interpret"))
def flash_attention_pallas_paged(
    q, k_pool, v_pool, page_table, kv_lens, q_offset, window,
    *, bq: int = 256, causal: bool = False,
    scale: float | None = None, interpret: bool = True,
    k_scale=None, v_scale=None,
):
    """q: (B, Hq, Sq, D) with Sq % bq == 0; k_pool/v_pool: (P, Hkv, ps, D);
    page_table: (B, n_pages) int32.  The KV grid axis walks LOGICAL pages;
    the BlockSpec index_map reads the prefetched page table to pick the
    PHYSICAL page, so block (b, j) fetches ``pool[table[b, j]]``.  The table
    arrives with out-of-strip (possibly stale) entries already clamped under
    the page-granular whilelt (ops._flash_paged), so the index_map never
    chases a freed id; the in-kernel predicate masks those blocks anyway.

    ``k_scale`` / ``v_scale``: ``(P, Hkv, ps)`` per-slot scale pools of a
    QUANTIZED cache; their (1, 1, ps) blocks ride the same table-driven
    index_map and the kernel widens the narrow K/V in register."""
    bsz, hq, sq, d = q.shape
    hkv, ps = k_pool.shape[1], k_pool.shape[2]
    n_pages = page_table.shape[1]
    group = hq // hkv
    assert sq % bq == 0, (sq, bq)
    n_q = sq // bq
    scale = (d ** -0.5) if scale is None else scale
    quant = k_scale is not None

    kern = _flash_kernel_paged_quant if quant else _flash_kernel_paged
    kernel = functools.partial(
        kern, bq=bq, page_size=ps, n_pages=n_pages, causal=causal, scale=scale)

    def q_map(b, h, i, j, table, kvl, qo, win):
        return (b, h, i, 0)

    def kv_map(b, h, i, j, table, kvl, qo, win):
        return (table[b, j], h // group, 0, 0)     # the gather: index vector

    def sc_map(b, h, i, j, table, kvl, qo, win):
        return (table[b, j], h // group, 0)        # scale rows: same walk

    in_specs = [
        pl.BlockSpec((1, 1, bq, d), q_map),
        pl.BlockSpec((1, 1, ps, d), kv_map),
        pl.BlockSpec((1, 1, ps, d), kv_map),
    ]
    operands = [q, k_pool, v_pool]
    if quant:
        in_specs += [pl.BlockSpec((1, 1, ps), sc_map),
                     pl.BlockSpec((1, 1, ps), sc_map)]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,                     # table, kv_lens, qoff, win
        grid=(bsz, hq, n_q, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, bq, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), kv_lens, q_offset, window, *operands)


@functools.partial(
    jax.jit,
    static_argnames=("bq", "bk", "causal", "scale", "interpret"))
def flash_attention_pallas(
    q, k, v, kv_lens, q_offset, window,
    *, bq: int = 256, bk: int = 512, causal: bool = False,
    scale: float | None = None, interpret: bool = True,
):
    """q: (B, Hq, Sq, D) with Sq % bq == 0; k/v: (B, Hkv, Skv, D), Skv % bk == 0.

    kv_lens: (B,) int32 valid KV length per row; q_offset: (B,) int32 absolute
    position of q[:, :, 0] (decode against a cache).  See ops.flash_attention
    for the padding/VL-selection wrapper.
    """
    bsz, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    n_q, n_kv = sq // bq, skv // bk
    scale = (d ** -0.5) if scale is None else scale

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, n_kv=n_kv, causal=causal, scale=scale)

    grid = (bsz, hq, n_q, n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),      # kv_lens
            pl.BlockSpec(memory_space=pl.ANY),      # q_offset
            pl.BlockSpec(memory_space=pl.ANY),      # window (dynamic)
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),     # m (running max)
            pltpu.VMEM((bq, 128), jnp.float32),     # l (running denominator)
            pltpu.VMEM((bq, d), jnp.float32),       # acc (unnormalized output)
        ],
        interpret=interpret,
    )(kv_lens, q_offset, window, q, k, v)
