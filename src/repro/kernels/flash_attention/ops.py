"""Public flash-attention op: VL-agnostic padding + kernel/XLA path switch."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import paging as _paging
from repro.core import vla

from . import ref as _ref
from .kernel import flash_attention_pallas, flash_attention_pallas_paged
from .xla_impl import flash_attention_xla, flash_attention_xla_paged


def _pick_blocks(sq: int, skv: int, d: int, dtype) -> tuple[int, int]:
    """Choose (bq, bk) MXU-aligned blocks that fit the VMEM budget.

    Working set ~ f32: q(bq,d) + k/v(bk,d)*2 + s(bq,bk) + acc(bq,d) + m/l(bq,128)*2.
    Policy: bq, bk in {128..512}, shrink to the problem when smaller.
    """
    bq = min(512, vla.pad_to_vl(sq, vla.LANE))
    bk = min(512, vla.pad_to_vl(skv, vla.LANE))
    budget = vla.VMEM_BYTES // 2
    while bq * bk * 4 + (bq + 2 * bk) * d * 4 + bq * (d + 256) * 4 > budget and bq > 128:
        bq //= 2
    while bq * bk * 4 + (bq + 2 * bk) * d * 4 + bq * (d + 256) * 4 > budget and bk > 128:
        bk //= 2
    return bq, bk


def flash_attention(
    q, k, v,
    *, kv_lens=None, causal: bool = False, window: int | None = None,
    q_offset=None, scale: float | None = None,
    impl: str = "kernel", bq: int | None = None, bk: int | None = None,
    interpret: bool = True, page_table=None, k_scale=None, v_scale=None,
):
    """Predicated attention.  q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D).

    - ``kv_lens``: (B,) valid KV lengths (ragged batches; defaults to Skv).
    - ``causal`` / ``window``: mask predicates (window = sliding local size).
    - ``q_offset``: (B,) absolute position of the first query row (decode);
      defaults to Skv - Sq under ``causal`` (suffix alignment) else 0.
    - ``impl``: "kernel" (Pallas TPU; interpret=True on CPU), "xla" (chunked
      lax.scan flash with custom VJP — the introspectable O(S)-memory path the
      dry-run lowers), or "naive" (quadratic oracle; tests only).
    - ``page_table``: (B, n_pages) int32 — PAGED mode: ``k``/``v`` are page
      POOLS of shape (P, Hkv, page_size, D) and attention reads K/V through
      the table (SVE §2.3.3 gather-load).  Forward-only (serving).
    - ``k_scale`` / ``v_scale``: (P, Hkv, page_size) per-slot scale pools of a
      QUANTIZED paged cache; the gather widens ``q8 * scale`` in register (the
      extending gather-load).  Paged mode only.
    """
    if page_table is not None:
        return _flash_paged(q, k, v, page_table, kv_lens=kv_lens,
                            causal=causal, window=window, q_offset=q_offset,
                            scale=scale, impl=impl, bq=bq,
                            interpret=interpret,
                            k_scale=k_scale, v_scale=v_scale)
    assert k_scale is None and v_scale is None, \
        "quantized K/V scales require page_table (paged mode)"
    b, hq, sq, d = q.shape
    skv = k.shape[2]
    if kv_lens is None:
        kv_lens = jnp.full((b,), skv, jnp.int32)
    else:
        kv_lens = jnp.asarray(kv_lens, jnp.int32)
    if q_offset is None:
        off = skv - sq if causal else 0
        q_offset = jnp.full((b,), off, jnp.int32)
    else:
        q_offset = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,))

    if impl == "naive":
        return _ref.mha_ref(q, k, v, kv_lens=kv_lens, causal=causal,
                            window=window, q_offset=q_offset, scale=scale)

    if bq is None or bk is None:
        bq_d, bk_d = _pick_blocks(sq, skv, d, q.dtype)
        bq = bq_d if bq is None else bq
        bk = bk_d if bk is None else bk
    bq = min(bq, vla.pad_to_vl(sq, 8))
    # pad Sq / Skv to block multiples; predicates mask the tails (no recompile
    # per shape — the VLA contract)
    sq_p, skv_p = vla.pad_to_vl(sq, bq), vla.pad_to_vl(skv, bk)
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    win = jnp.asarray(2 ** 30 if window is None else window,
                      jnp.int32).reshape((1,))
    if impl == "xla":
        scale_f = float(d ** -0.5) if scale is None else float(scale)
        out = flash_attention_xla(q, k, v, kv_lens, q_offset, win[0],
                                  causal=causal, scale=scale_f, bq=bq, bk=bk)
    else:
        out = flash_attention_pallas(
            q, k, v, kv_lens, q_offset, win, bq=bq, bk=bk, causal=causal,
            scale=scale, interpret=interpret)
    return out[:, :, :sq, :]


def _flash_paged(q, k_pool, v_pool, page_table, *, kv_lens, causal, window,
                 q_offset, scale, impl, bq, interpret,
                 k_scale=None, v_scale=None):
    """Paged dispatch: pools + page table instead of dense K/V."""
    b, hq, sq, d = q.shape
    ps = k_pool.shape[2]
    n_pages = page_table.shape[1]
    skv = n_pages * ps                               # logical KV extent
    if kv_lens is None:
        kv_lens = jnp.full((b,), skv, jnp.int32)
    else:
        kv_lens = jnp.asarray(kv_lens, jnp.int32)
    if q_offset is None:
        off = skv - sq if causal else 0
        q_offset = jnp.full((b,), off, jnp.int32)
    else:
        q_offset = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,))
    page_table = jnp.asarray(page_table, jnp.int32)
    # govern the table walk with the page-granular whilelt ONCE, for every
    # impl: out-of-strip entries may be stale (freed and reallocated ids),
    # so clamp them to page 0 before any gather / index_map chases them —
    # their contribution is masked by the element predicate regardless
    page_table = jnp.where(_paging.page_whilelt(kv_lens, n_pages, ps),
                           page_table, 0)

    if impl == "naive":
        # quadratic oracle over the gathered dense view (tests only) — the
        # extending gather widens quantized pools here too
        k = _paging.gather_pages(k_pool, page_table, scale=k_scale)
        v = _paging.gather_pages(v_pool, page_table, scale=v_scale)
        return _ref.mha_ref(q, k, v, kv_lens=kv_lens, causal=causal,
                            window=window, q_offset=q_offset, scale=scale)

    if bq is None:
        bq, _ = _pick_blocks(sq, skv, d, q.dtype)
    bq = min(bq, vla.pad_to_vl(sq, 8))
    sq_p = vla.pad_to_vl(sq, bq)
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    win = jnp.asarray(2 ** 30 if window is None else window,
                      jnp.int32).reshape((1,))
    scale_f = float(d ** -0.5) if scale is None else float(scale)
    if impl == "xla":
        out = flash_attention_xla_paged(
            q, k_pool, v_pool, page_table, kv_lens, q_offset, win[0],
            causal=causal, scale=scale_f, bq=bq,
            k_scale=k_scale, v_scale=v_scale)
    else:
        out = flash_attention_pallas_paged(
            q, k_pool, v_pool, page_table, kv_lens, q_offset, win,
            bq=bq, causal=causal, scale=scale_f, interpret=interpret,
            k_scale=k_scale, v_scale=v_scale)
    return out[:, :, :sq, :]
