"""Pure-jnp oracle for predicated flash attention.

Supports everything the kernel supports: GQA, causal masks, sliding windows
(gemma3 local layers), ragged KV lengths (whilelt predicates), and a dynamic
query offset (decode against a longer cache).  This is also the XLA execution
path used by the dry-run (pallas_call does not lower to the CPU backend and
is opaque to cost_analysis; see DESIGN.md).
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = float("-inf")


def attention_mask(sq, skv, *, kv_lens=None, causal=False, window=None, q_offset=0):
    """Boolean (B?, Sq, Skv) predicate, True = attend.  Pure whilelt algebra.

    ``q_offset`` may be a scalar or a (B,) vector (per-row decode positions);
    ``window`` may be a python int or a traced scalar (dynamic local/global).
    """
    qoff = jnp.asarray(q_offset, jnp.int32)
    batched = (kv_lens is not None) or qoff.ndim == 1
    if qoff.ndim == 0:
        qoff = qoff[None]
    qp = (qoff[:, None] + jnp.arange(sq, dtype=jnp.int32)[None, :])[:, :, None]
    kp = jnp.arange(skv, dtype=jnp.int32)[None, None, :]
    m = jnp.ones((qoff.shape[0], sq, skv), bool)
    if causal:
        m &= qp >= kp
    if window is not None:
        m &= kp > (qp - jnp.asarray(window, jnp.int32))
    if kv_lens is not None:
        m = m & (kp < jnp.asarray(kv_lens, jnp.int32)[:, None, None])
    return m if batched else m[0]


def mha_ref(q, k, v, *, kv_lens=None, causal=False, window=None, q_offset=None,
            scale=None):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D).  Returns (B, Hq, Sq, D).

    Rows whose predicate is empty (no attendable key) return 0 — the zeroing-
    predication convention used throughout the framework.
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    if q_offset is None:
        q_offset = (skv - sq) if causal else 0  # suffix alignment, as the kernel

    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) * scale
    mask = attention_mask(sq, skv, kv_lens=kv_lens, causal=causal,
                          window=window, q_offset=q_offset)
    mask = mask[:, None] if mask.ndim == 3 else mask[None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    row_any = mask.any(axis=-1, keepdims=True)
    m = jnp.max(jnp.where(mask, logits, -1e30), axis=-1, keepdims=True)
    p = jnp.where(mask, jnp.exp(logits - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    out = jnp.where(row_any, out / jnp.maximum(l, 1e-30), 0.0)
    return out.astype(q.dtype)
