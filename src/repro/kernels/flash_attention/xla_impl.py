"""Memory-efficient flash attention in pure XLA (lax.scan blocks + custom VJP).

This is the execution path the dry-run lowers (``impl="xla"``): identical
online-softmax blocking to the Pallas kernel — so ``cost_analysis`` sees the
real FLOPs and ``memory_analysis`` sees the real O(S) working set — but built
from jnp ops, so it compiles for any backend and differentiates via a
hand-written flash backward (block-recomputed, two-pass dq / dkdv).

All masks are whilelt-predicates built from scalar bounds per block, exactly
as in kernel.py: causal, dynamic sliding window, ragged kv_lens, per-row
q_offset (decode) — one code path for every attention variant (SVE C2/C3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _block_pred(iq, ik, bq, bk, kv_lens, q_offset, window, causal):
    """(B, bq, bk) predicate for block (iq, ik).  Pure whilelt algebra."""
    qpos = (q_offset[:, None, None]
            + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (1, bq, bk), 1))
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bq, bk), 2)
    pred = kpos < kv_lens[:, None, None]
    if causal:
        pred &= qpos >= kpos
    pred &= kpos > (qpos - window)
    return pred


def _split_q(q, bq):
    b, h, sq, d = q.shape
    return q.reshape(b, h, sq // bq, bq, d).transpose(2, 0, 1, 3, 4)


def _split_kv(k, bk):
    b, hkv, skv, d = k.shape
    return k.reshape(b, hkv, skv // bk, bk, d).transpose(2, 0, 1, 3, 4)


def _merge_q(blocks):
    nq, b, h, bq, d = blocks.shape
    return blocks.transpose(1, 2, 0, 3, 4).reshape(b, h, nq * bq, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def _flash(q, k, v, kv_lens, q_offset, window, causal, scale, bq, bk):
    out, _ = _flash_fwd_impl(q, k, v, kv_lens, q_offset, window, causal,
                             scale, bq, bk)
    return out


def _flash_fwd_impl(q, k, v, kv_lens, q_offset, window, causal, scale, bq, bk):
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = h // hkv
    f32 = jnp.float32
    qs = _split_q(q.astype(f32), bq)                       # (nq,B,H,bq,D)
    qs = qs.reshape(qs.shape[0], b, hkv, g, bq, d)         # GQA: h-major groups
    ks = _split_kv(k.astype(f32), bk)                      # (nk,B,Hkv,bk,D)
    vs = _split_kv(v.astype(f32), bk)
    nk = ks.shape[0]

    def q_block(_, xs):
        qb, iq = xs                                        # (B,Hkv,G,bq,D)

        def kv_block(carry, xs2):
            m, l, acc = carry
            kb, vb, ik = xs2
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb) * scale
            pred = _block_pred(iq, ik, bq, bk, kv_lens, q_offset, window,
                               causal)[:, None, None]      # (B,1,1,bq,bk)
            s = jnp.where(pred, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.where(pred, jnp.exp(s - m_new[..., None]), 0.0)
            l = alpha * l + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, vb)
            return (m_new, l, acc), None

        init = (jnp.full((b, hkv, g, bq), NEG_INF, f32),
                jnp.zeros((b, hkv, g, bq), f32),
                jnp.zeros((b, hkv, g, bq, d), f32))
        (m, l, acc), _ = jax.lax.scan(
            kv_block, init, (ks, vs, jnp.arange(nk, dtype=jnp.int32)))
        out_b = jnp.where(l[..., None] > 0.0,
                          acc / jnp.maximum(l[..., None], 1e-30), 0.0)
        lse_b = m + jnp.log(jnp.maximum(l, 1e-30))         # (B,Hkv,G,bq)
        return None, (out_b, lse_b)

    nq = qs.shape[0]
    _, (out_blocks, lse_blocks) = jax.lax.scan(
        q_block, None, (qs, jnp.arange(nq, dtype=jnp.int32)))
    out = out_blocks.transpose(1, 2, 3, 0, 4, 5).reshape(b, h, sq, d)
    lse = lse_blocks.transpose(1, 2, 3, 0, 4).reshape(b, h, sq)
    return out.astype(q.dtype), lse


def _flash_fwd(q, k, v, kv_lens, q_offset, window, causal, scale, bq, bk):
    out, lse = _flash_fwd_impl(q, k, v, kv_lens, q_offset, window, causal,
                               scale, bq, bk)
    return out, (q, k, v, out, lse, kv_lens, q_offset, window)


def _flash_bwd(causal, scale, bq, bk, res, dout):
    q, k, v, out, lse, kv_lens, q_offset, window = res
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = h // hkv
    f32 = jnp.float32
    nq, nk = sq // bq, skv // bk

    qs = _split_q(q.astype(f32), bq).reshape(nq, b, hkv, g, bq, d)
    dos = _split_q(dout.astype(f32), bq).reshape(nq, b, hkv, g, bq, d)
    ls = _split_q(lse[..., None], bq)[..., 0].reshape(nq, b, hkv, g, bq)
    # delta = rowsum(dO * O)
    delta = jnp.sum(dout.astype(f32) * out.astype(f32), axis=-1)
    ds_blocks = _split_q(delta[..., None], bq)[..., 0].reshape(nq, b, hkv, g, bq)
    ks = _split_kv(k.astype(f32), bk)
    vs = _split_kv(v.astype(f32), bk)

    # ---- pass 1: dq (scan q blocks; inner scan kv) ----
    def q_block(_, xs):
        qb, dob, lb, db, iq = xs

        def kv_block(dqb, xs2):
            kb, vb, ik = xs2
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb) * scale
            pred = _block_pred(iq, ik, bq, bk, kv_lens, q_offset, window,
                               causal)[:, None, None]
            p = jnp.where(pred, jnp.exp(s - lb[..., None]), 0.0)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", dob, vb)
            ds = p * (dp - db[..., None]) * scale
            dqb = dqb + jnp.einsum("bhgqk,bhkd->bhgqd", ds, kb)
            return dqb, None

        dqb, _ = jax.lax.scan(kv_block, jnp.zeros_like(qb),
                              (ks, vs, jnp.arange(nk, dtype=jnp.int32)))
        return None, dqb

    _, dq_blocks = jax.lax.scan(
        q_block, None, (qs, dos, ls, ds_blocks, jnp.arange(nq, dtype=jnp.int32)))
    dq = dq_blocks.transpose(1, 2, 3, 0, 4, 5).reshape(b, h, sq, d)

    # ---- pass 2: dk, dv (scan kv blocks; inner scan q) ----
    def kv_block2(_, xs):
        kb, vb, ik = xs

        def q_block2(carry, xs2):
            dkb, dvb = carry
            qb, dob, lb, db, iq = xs2
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb) * scale
            pred = _block_pred(iq, ik, bq, bk, kv_lens, q_offset, window,
                               causal)[:, None, None]
            p = jnp.where(pred, jnp.exp(s - lb[..., None]), 0.0)
            dvb = dvb + jnp.einsum("bhgqk,bhgqd->bhkd", p, dob)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", dob, vb)
            ds = p * (dp - db[..., None]) * scale
            dkb = dkb + jnp.einsum("bhgqk,bhgqd->bhkd", ds, qb)
            return (dkb, dvb), None

        init = (jnp.zeros((b, hkv, bk, d), f32), jnp.zeros((b, hkv, bk, d), f32))
        (dkb, dvb), _ = jax.lax.scan(
            q_block2, init,
            (qs, dos, ls, ds_blocks, jnp.arange(nq, dtype=jnp.int32)))
        return None, (dkb, dvb)

    _, (dk_blocks, dv_blocks) = jax.lax.scan(
        kv_block2, None, (ks, vs, jnp.arange(nk, dtype=jnp.int32)))
    dk = dk_blocks.transpose(1, 2, 0, 3, 4).reshape(b, hkv, skv, d)
    dv = dv_blocks.transpose(1, 2, 0, 3, 4).reshape(b, hkv, skv, d)

    zero_i = lambda t: jnp.zeros_like(t)  # int operands: symbolic zero grads
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            zero_i(kv_lens), zero_i(q_offset), zero_i(window))


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_xla(q, k, v, kv_lens, q_offset, window, *, causal,
                        scale, bq, bk):
    """Public entry (shapes already padded to block multiples by ops.py)."""
    return _flash(q, k, v, kv_lens, q_offset, window, causal, scale, bq, bk)


# ---------------------------------------------------------------------------
# Paged forward: K/V blocks ARE pages, fetched through the page table
# ---------------------------------------------------------------------------

def flash_attention_xla_paged(q, k_pool, v_pool, page_table, kv_lens,
                              q_offset, window, *, causal, scale, bq,
                              k_scale=None, v_scale=None):
    """Flash forward over a PAGED KV cache (SVE §2.3.3 gather-load).

    k_pool / v_pool: ``(P, Hkv, page_size, D)`` page pools; ``page_table``:
    ``(B, n_pages) int32``.  The kv-block scan walks LOGICAL pages and fetches
    each lane's physical page with a ``jnp.take`` on the pool — the index
    vector, not the layout, addresses memory, so the same kernel serves any
    physical placement (allocation order, prefix-shared pages, reuse).  The
    online-softmax math is identical to the dense path with ``bk ==
    page_size``; logical positions come from the page index, so masks are
    unchanged.  Serving/decode only — no VJP.  ``page_table`` arrives with
    out-of-strip (possibly stale) entries already clamped to page 0 under the
    page-granular whilelt — ops._flash_paged governs the walk once for every
    impl.

    ``k_scale`` / ``v_scale``: ``(P, Hkv, page_size)`` per-slot scale pools
    of a QUANTIZED cache — the same ``jnp.take`` that fetches a page fetches
    its scales and widens the narrow elements in register (SVE §2.3.3
    extending gather-load): ``kb = q8 * scale``.
    """
    b, h, sq, d = q.shape
    hkv, ps = k_pool.shape[1], k_pool.shape[2]
    n_pg = page_table.shape[1]
    g = h // hkv
    f32 = jnp.float32
    nq = sq // bq
    qs = _split_q(q.astype(f32), bq).reshape(nq, b, hkv, g, bq, d)
    table = page_table

    def q_block(_, xs):
        qb, iq = xs

        def kv_block(carry, ik):
            m, l, acc = carry
            pids = table[:, ik]
            kb = jnp.take(k_pool, pids, axis=0).astype(f32)   # (B,Hkv,ps,D)
            vb = jnp.take(v_pool, pids, axis=0).astype(f32)
            if k_scale is not None:
                kb = kb * jnp.take(k_scale, pids, axis=0)[..., None]
            if v_scale is not None:
                vb = vb * jnp.take(v_scale, pids, axis=0)[..., None]
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb) * scale
            pred = _block_pred(iq, ik, bq, ps, kv_lens, q_offset, window,
                               causal)[:, None, None]
            s = jnp.where(pred, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.where(pred, jnp.exp(s - m_new[..., None]), 0.0)
            l = alpha * l + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, vb)
            return (m_new, l, acc), None

        init = (jnp.full((b, hkv, g, bq), NEG_INF, f32),
                jnp.zeros((b, hkv, g, bq), f32),
                jnp.zeros((b, hkv, g, bq, d), f32))
        (m, l, acc), _ = jax.lax.scan(kv_block, init,
                                      jnp.arange(n_pg, dtype=jnp.int32))
        out_b = jnp.where(l[..., None] > 0.0,
                          acc / jnp.maximum(l[..., None], 1e-30), 0.0)
        return None, out_b

    _, out_blocks = jax.lax.scan(q_block, None,
                                 (qs, jnp.arange(nq, dtype=jnp.int32)))
    out = out_blocks.transpose(1, 2, 3, 0, 4, 5).reshape(b, h, sq, d)
    return out.astype(q.dtype)
