from .ops import build_dispatch, moe_positions  # noqa: F401
