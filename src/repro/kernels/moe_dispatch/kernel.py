"""MoE dispatch position-assignment kernel (Pallas TPU).

The serialized heart of capacity-based MoE routing is a running per-expert
counter: assignment (t, k) lands at position ``count_so_far[expert]`` within
its expert's buffer.  This kernel strip-mines tokens into VL-sized tiles
(grid axis sequential) and carries the (1, E) counter vector in VMEM scratch —
the cluster-scale cousin of the paper's ``incp`` (advance induction by the
predicate popcount).  Within a tile the ranks come from a one-hot matrix
cumsum, i.e. vectorized; across tiles the carry is the loop-carried scalar
state, exactly the split of paper Fig. 6 (vectorizable body + serial carry).

Capacity is NOT applied here — the kernel reports raw ranks; ops.py derives
the keep-predicate ``pos < capacity`` (the FFR partition) so callers can also
observe overflow statistics (aux losses need them).

Tile geometry: tokens_per_tile x E one-hot in int32; for E=64..128 and tile
512 that is a 512x128 i32 buffer = 256 KiB — VMEM-friendly, lane-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import vla


def _dispatch_kernel(ids_ref, pos_ref, counts_ref, counts_scr,
                     *, tile: int, k: int, e_pad: int, n_tiles: int):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        counts_scr[...] = jnp.zeros_like(counts_scr[...])

    ids = ids_ref[...].reshape(tile * k, 1)                     # flattened order
    lanes = jax.lax.broadcasted_iota(jnp.int32, (tile * k, e_pad), 1)
    onehot = (ids == lanes).astype(jnp.int32)                   # invalid ids -> 0 row
    carry = counts_scr[0:1, :]                                  # (1, E)
    excl = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.sum((excl + carry) * onehot, axis=1)              # rank per assignment
    pos_ref[...] = pos.reshape(tile, k)
    counts_scr[0:1, :] = carry + jnp.sum(onehot, axis=0, keepdims=True)

    @pl.when(pid == n_tiles - 1)
    def _emit():
        counts_ref[...] = counts_scr[0:1, :]


@functools.partial(jax.jit, static_argnames=("n_experts", "tile", "interpret"))
def moe_positions_pallas(expert_ids, *, n_experts: int, tile: int = 512,
                         interpret: bool = True):
    """expert_ids: (T, K) int32; T % tile == 0 (ops.py pads with -1).
    Returns pos (T, K) int32 and counts (E,) int32."""
    t, k = expert_ids.shape
    assert t % tile == 0, (t, tile)
    e_pad = vla.pad_to_vl(n_experts, vla.LANE)
    n_tiles = t // tile
    kernel = functools.partial(_dispatch_kernel, tile=tile, k=k, e_pad=e_pad,
                               n_tiles=n_tiles)
    pos, counts = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((tile, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tile, k), lambda i: (i, 0)),
            pl.BlockSpec((1, e_pad), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, k), jnp.int32),
            jax.ShapeDtypeStruct((1, e_pad), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((8, e_pad), jnp.int32)],
        interpret=interpret,
    )(expert_ids)
    return pos, counts[0, :n_experts]
