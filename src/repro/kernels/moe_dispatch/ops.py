"""Public MoE dispatch ops: position kernel + gather/scatter table builder.

The gather/scatter (SVE C8) happens here in XLA-land so pjit can turn it into
all-to-alls under expert parallelism; the Pallas kernel supplies the serial
counter ranks.  Overflowed assignments form the cleared lanes of the dispatch
partition (FFR analogue, see ref.py).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import vla

from .kernel import moe_positions_pallas
from .ref import moe_positions_ref


def moe_positions(expert_ids, n_experts: int, *, tile: int = 512,
                  impl: str = "kernel", interpret: bool = True):
    """Rank of each (token, slot) assignment within its expert + totals."""
    t, k = expert_ids.shape
    if impl == "xla":
        return moe_positions_ref(expert_ids, n_experts)
    t_pad = vla.pad_to_vl(t, tile)
    ids = expert_ids
    if t_pad != t:
        ids = jnp.pad(ids, ((0, t_pad - t), (0, 0)), constant_values=-1)
    pos, counts = moe_positions_pallas(ids, n_experts=n_experts, tile=tile,
                                       interpret=interpret)
    return pos[:t], counts


def build_dispatch(expert_ids, gates, n_experts: int, capacity: int,
                   *, impl: str = "kernel", interpret: bool = True):
    """Build the dispatch tables for a capacity-C MoE layer.

    Returns dict with:
      token_table: (E, C) int32 — source token for each expert slot, or T
                   (one-past-last, a zero row in the padded activations) for
                   empty slots;
      slot_of:     (T, K) int32 — e*C + pos for kept assignments, else E*C
                   (points at a zero row of the flattened expert outputs);
      keep:        (T, K) bool — the dispatch partition (pos < capacity);
      gates:       (T, K) f32  — combine weights, zeroed on dropped lanes;
      counts:      (E,) int32  — raw demand per expert (for aux losses);
      dropped:     ()  int32   — number of dropped assignments.
    """
    t, k = expert_ids.shape
    pos, counts = moe_positions(expert_ids, n_experts, impl=impl,
                                interpret=interpret)
    valid = (expert_ids >= 0) & (expert_ids < n_experts)
    keep = valid & (pos < capacity)

    # scatter (token -> expert slot); dropped lanes go to the overflow slot
    flat_slot = jnp.where(keep, expert_ids * capacity + pos, n_experts * capacity)
    token_ids = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[:, None], (t, k))
    token_table = jnp.full((n_experts * capacity + 1,), t, jnp.int32)
    token_table = token_table.at[flat_slot.reshape(-1)].set(
        token_ids.reshape(-1), mode="drop")
    token_table = token_table[:-1].reshape(n_experts, capacity)

    gates_kept = jnp.where(keep, gates, 0.0).astype(gates.dtype)
    slot_of = jnp.where(keep, expert_ids * capacity + pos, n_experts * capacity)
    return dict(
        token_table=token_table,
        slot_of=slot_of.astype(jnp.int32),
        keep=keep,
        gates=gates_kept,
        counts=counts,
        dropped=jnp.sum((valid & ~keep).astype(jnp.int32)),
    )
