"""Pure-jnp oracle for MoE dispatch position assignment + a naive-loop
reference for the whole dispatch/combine (used by layer tests).

Dispatch semantics (Switch-style, capacity-factor dropping): assignments are
ranked in flattened (token-major, slot-minor) order; each expert accepts its
first ``capacity`` assignments, the rest are DROPPED.  Dropped lanes are the
framework's FFR analogue: the speculative "load" (routing) of an overflowing
token faults and its lane is cleared from the dispatch partition; the token's
residual path still carries its activation (like the retry granted to the
first faulting lane).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def moe_positions_ref(expert_ids, n_experts: int):
    """expert_ids: (T, K) int32 in [0, E) (or out-of-range = invalid).
    Returns pos: (T, K) int32 — the rank of each assignment within its expert
    (flattened token-major order), and counts: (E,) total assignments."""
    t, k = expert_ids.shape
    flat = expert_ids.reshape(t * k)
    onehot = (flat[:, None] == jnp.arange(n_experts)[None, :]).astype(jnp.int32)
    excl = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.sum(excl * onehot, axis=1)
    counts = jnp.sum(onehot, axis=0)
    return pos.reshape(t, k), counts


def moe_ffn_loop_ref(x, expert_ids, gates, w_up, w_down, capacity: int):
    """Naive python-loop MoE FFN with capacity dropping (numpy; test oracle).

    x: (T, D); expert_ids/gates: (T, K); w_up: (E, D, F); w_down: (E, F, D).
    Expert activation: relu.  Returns (T, D) float32.
    """
    x = np.asarray(x, np.float32)
    ids = np.asarray(expert_ids)
    g = np.asarray(gates, np.float32)
    w_up = np.asarray(w_up, np.float32)
    w_down = np.asarray(w_down, np.float32)
    t, k = ids.shape
    e = w_up.shape[0]
    counts = np.zeros(e, np.int64)
    y = np.zeros_like(x)
    for tok in range(t):
        for slot in range(k):
            ex = int(ids[tok, slot])
            if ex < 0 or ex >= e:
                continue
            if counts[ex] >= capacity:
                counts[ex] += 1          # overflow: dropped ("faulted lane")
                continue
            counts[ex] += 1
            h = np.maximum(x[tok] @ w_up[ex], 0.0)
            y[tok] += g[tok, slot] * (h @ w_down[ex])
    return y
