from .ops import ssd_scan, ssd_decode_step  # noqa: F401
