"""Mamba2 SSD chunked scan for TPU (Pallas).

VLA mapping (DESIGN.md C1): the chunk length Q is this kernel's vector
length.  One kernel source runs at any Q; results are Q-invariant (tested),
exactly as SVE binaries are VL-invariant.  Ragged sequence tails are handled
by *predicating dt to zero* (decay=exp(0)=1, zero input, zero output
contribution) — predication, not shape specialization.

Blocking: grid (B, H, S/Q) with the chunk axis innermost and sequential; the
(P, N) state lives in VMEM scratch across chunks.  Per-chunk working set for
Q=128, P=64, N=128 in f32: x (Q,P) 32 KiB + B,C (Q,N) 64 KiB + L (Q,Q) 64 KiB
+ state (P,N) 32 KiB — far inside the v5e VMEM budget; matmul dims are
MXU-aligned multiples of 64/128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_head_ref,                       # (H,) ANY: A per head
                x_ref, dt_ref, b_ref, c_ref,      # blocked inputs
                h0_ref,                           # (1, 1, P, N) initial state
                y_ref, hout_ref,                  # blocked outputs
                h_scr,                            # (P, N) VMEM state
                *, q: int, n_chunks: int):
    h = pl.program_id(1)
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (Q,)
    bm = b_ref[0].astype(jnp.float32)              # (Q, N)
    cm = c_ref[0].astype(jnp.float32)              # (Q, N)
    A = a_head_ref[h]

    a = dt * A                                     # (Q,) log-decay, <= 0
    cum = jnp.cumsum(a)                            # inclusive
    # decay matrix L[i,j] = exp(cum_i - cum_j) for i>=j else 0
    iq = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tri = iq >= jq                                 # causal predicate
    L = jnp.where(tri, jnp.exp(cum[:, None] - cum[None, :]), 0.0)

    # intra-chunk (attention-like) term
    att = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (Q, Q)
    att = att * L * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (Q, P)

    # inter-chunk term: y += exp(cum_i) * C_i @ h_prev^T
    hprev = h_scr[...]                             # (P, N)
    y_inter = jax.lax.dot_general(cm, hprev, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (Q, P)
    y = y + y_inter * jnp.exp(cum)[:, None]

    # state update: h = exp(cum_Q) h_prev + sum_j exp(cum_Q - cum_j) dt_j x_j B_j^T
    w = jnp.exp(cum[-1] - cum) * dt                # (Q,)
    upd = jax.lax.dot_general(x * w[:, None], bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    h_scr[...] = jnp.exp(cum[-1]) * hprev + upd

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        hout_ref[0, 0] = h_scr[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, A, B, C, h0=None, *, chunk: int = 128,
                    interpret: bool = True):
    """x: (Bz, S, H, P); dt: (Bz, S, H); A: (H,); B, C: (Bz, S, N);
    h0: (Bz, H, P, N) f32 initial state or None (zeros) — chunked-prefill
    resume seeds the VMEM state scratch at chunk 0 instead of zeroing it.
    S % chunk == 0 (ops.py pads + predicates dt).  Returns (y, h_final)."""
    bz, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    if h0 is None:
        h0 = jnp.zeros((bz, h, p, n), jnp.float32)

    kernel = functools.partial(_ssd_kernel, q=chunk, n_chunks=nc)
    grid = (bz, h, nc)
    y, hout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),                       # A (H,)
            pl.BlockSpec((1, chunk, 1, p), lambda b, hh, c: (b, c, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, hh, c: (b, c, hh)),
            pl.BlockSpec((1, chunk, n), lambda b, hh, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, hh, c: (b, c, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, hh, c: (b, hh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b, hh, c: (b, c, hh, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, hh, c: (b, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((bz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(A, x, dt, B, C, h0)
    return y, hout
