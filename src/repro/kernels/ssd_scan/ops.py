"""Public SSD ops: padding/predication wrapper + single-token decode step."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import vla

from .kernel import ssd_scan_pallas
from .ref import ssd_chunked_ref, ssd_ref  # noqa: F401  (oracle re-export)


def ssd_scan(x, dt, A, B, C, D=None, *, seq_lens=None, h0=None,
             chunk: int = 128, impl: str = "kernel", interpret: bool = True):
    """Chunk-size-agnostic SSD scan.

    x: (Bz, S, H, P); dt: (Bz, S, H) (positive; e.g. softplus upstream);
    A: (H,) negative; B, C: (Bz, S, N); D: (H,) skip or None;
    seq_lens: (Bz,) ragged valid lengths — implemented by *predicating dt to
    zero* past the end (SVE zeroing predication; state then carries unchanged
    and padded rows contribute nothing);
    h0: (Bz, H, P, N) initial state or None (zeros) — chunked-prefill resume:
    scanning a suffix from the carried state equals scanning the whole
    sequence bit-for-bit when the resume offset is a multiple of ``chunk``
    (the chunk_step sequence is then identical; padded tail steps are exact
    identities because dt=0 makes decay exp(0)=1 and the update exactly 0).

    Returns (y, h_final): y (Bz, S, H, P), h_final (Bz, H, P, N) f32.
    """
    bz, s, h, p = x.shape
    if seq_lens is not None:
        pos = jnp.arange(s, dtype=jnp.int32)[None, :, None]
        dt = jnp.where(pos < jnp.asarray(seq_lens, jnp.int32)[:, None, None], dt, 0.0)

    s_p = vla.pad_to_vl(s, chunk)
    if s_p != s:
        pad = [(0, 0), (0, s_p - s)]
        x = jnp.pad(x, pad + [(0, 0), (0, 0)])
        dt = jnp.pad(dt, pad + [(0, 0)])          # dt=0 => inert lanes
        B = jnp.pad(B, pad + [(0, 0)])
        C = jnp.pad(C, pad + [(0, 0)])

    if impl == "xla":
        y, hT = ssd_chunked_ref(x, dt, A, B, C, None, h0=h0, chunk=chunk)
    else:
        if h0 is None:
            h0 = jnp.zeros((bz, h, p, B.shape[-1]), jnp.float32)
        y, hT = ssd_scan_pallas(x, dt, A, B, C, h0.astype(jnp.float32),
                                chunk=chunk, interpret=interpret)

    y = y[:, :s]
    if D is not None:
        y = (y.astype(jnp.float32)
             + D.astype(jnp.float32)[None, None, :, None]
             * x[:, :s].astype(jnp.float32)).astype(y.dtype)
    return y, hT


def ssd_decode_step(x_t, dt_t, A, B_t, C_t, h, D=None):
    """One-token SSD recurrence for serving.

    x_t: (Bz, H, P); dt_t: (Bz, H); B_t, C_t: (Bz, N); h: (Bz, H, P, N).
    Returns (y_t, h_new).  This is the constant-memory long-context decode
    path (long_500k cells for SSM/hybrid archs).
    """
    f32 = jnp.float32
    decay = jnp.exp(dt_t.astype(f32) * A.astype(f32)[None, :])       # (Bz,H)
    upd = (dt_t.astype(f32)[..., None, None]
           * x_t.astype(f32)[..., :, None] * B_t.astype(f32)[:, None, None, :])
    h_new = decay[..., None, None] * h.astype(f32) + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, C_t.astype(f32))
    if D is not None:
        y = y + D.astype(f32)[None, :, None] * x_t.astype(f32)
    return y.astype(x_t.dtype), h_new
