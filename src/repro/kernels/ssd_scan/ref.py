"""Pure-jnp oracles for the Mamba2 SSD (state-space duality) scan.

Semantics per head (arXiv:2405.21060, SSD recurrence):

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * (x_t outer B_t)        # (P, N)
    y_t = h_t @ C_t + D * x_t                                     # (P,)

``ssd_ref`` is the strictly sequential oracle (lax.scan over time).
``ssd_chunked_ref`` is the chunked/blocked algorithm the Pallas kernel
implements — quadratic-in-chunk "attention-like" intra term + inter-chunk
state carry.  Both must agree for every chunk size (the VLA contract: chunk
size is this kernel's vector length).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C, D=None, h0=None):
    """x: (Bz, S, H, P); dt: (Bz, S, H) positive; A: (H,) negative;
    B, C: (Bz, S, N) (single group, broadcast over heads);
    D: (H,) or None; h0: (Bz, H, P, N) or None.
    Returns y: (Bz, S, H, P), h_final: (Bz, H, P, N).  All compute f32.
    """
    bz, s, h, p = x.shape
    n = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(hst, inp):
        xt, dtt, bt, ct = inp                    # (Bz,H,P), (Bz,H), (Bz,N), (Bz,N)
        decay = jnp.exp(dtt * Af[None, :])       # (Bz,H)
        upd = (dtt[..., None, None] * xt[..., :, None] * bt[:, None, None, :])
        hst = decay[..., None, None] * hst + upd
        yt = jnp.einsum("bhpn,bn->bhp", hst, ct)
        return hst, yt

    h0 = jnp.zeros((bz, h, p, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    hT, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                   # (Bz, S, H, P)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype), hT


def _segsum(a):
    """L[i, j] = sum_{k in (j, i]} a_k for i >= j else -inf.  a: (..., Q)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]           # cum_i - cum_j
    i = jnp.arange(q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked_ref(x, dt, A, B, C, D=None, h0=None, chunk: int = 64):
    """Chunked SSD — the algorithm the Pallas kernel implements, in pure jnp.

    This is also the XLA execution path used by dry-run lowering (the Pallas
    call is TPU-only and opaque to cost_analysis).
    """
    bz, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    f32 = jnp.float32

    xf = x.astype(f32).reshape(bz, nc, chunk, h, p)
    dtf = dt.astype(f32).reshape(bz, nc, chunk, h)
    Bf = B.astype(f32).reshape(bz, nc, chunk, n)
    Cf = C.astype(f32).reshape(bz, nc, chunk, n)
    a = dtf * A.astype(f32)[None, None, None, :]         # (bz, nc, Q, h) log-decay

    def chunk_step(hprev, inp):
        xc, dtc, bc, cc, ac = inp                        # leading axis bz
        cum = jnp.cumsum(ac, axis=1)                     # (bz, Q, h) inclusive
        L = jnp.exp(_segsum(jnp.moveaxis(ac, 1, 2)))     # (bz, h, Q, Q)
        att = jnp.einsum("bqn,bkn->bqk", cc, bc)         # (bz, Q, Q) shared heads
        att = att[:, None] * L * dtc.transpose(0, 2, 1)[:, :, None, :]  # *dt_j
        y_intra = jnp.einsum("bhqk,bkhp->bqhp", att, xc)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bqn,bhpn->bqhp", cc, hprev) * \
            jnp.exp(cum)[:, :, :, None]
        # state update
        wexp = jnp.exp(cum[:, -1:, :] - cum) * dtc       # (bz, Q, h)
        upd = jnp.einsum("bqhp,bqn,bqh->bhpn", xc, bc, wexp)
        hnew = jnp.exp(cum[:, -1, :])[:, :, None, None] * hprev + upd
        return hnew, y_intra + y_inter

    h0 = jnp.zeros((bz, h, p, n), f32) if h0 is None else h0.astype(f32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xf, dtf, Bf, Cf, a))
    hT, ys = jax.lax.scan(chunk_step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bz, s, h, p)
    if D is not None:
        y = y + D.astype(f32)[None, None, :, None] * x.astype(f32)
    return y.astype(x.dtype), hT
