import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ---------------------------------------------------------------------------
# Multi-pod dry-run driver (deliverable e).
#
# For every (arch x input-shape x mesh) cell: resolve shardings from the
# logical-axis rules, jit the step function, .lower().compile() against the
# production mesh, and record memory_analysis / cost_analysis / collective
# bytes (parsed from the optimized HLO) to JSON for the roofline analysis.
#
# NOTE: arguments are parsed BEFORE importing jax so tests can shrink the
# forced host-device count (jax locks it on first init).
# ---------------------------------------------------------------------------

import argparse
import json
import re
import sys
import time


def _parse_args(argv=None):
    p = argparse.ArgumentParser(description="multi-pod dry-run")
    p.add_argument("--arch", default=None, help="arch id (default: all)")
    p.add_argument("--shape", default=None, help="shape name (default: all)")
    p.add_argument("--mesh", default="single", choices=["single", "multi", "custom"])
    p.add_argument("--mesh-shape", default=None,
                   help="custom mesh, e.g. '4,4' or '2,4,4' (tests)")
    p.add_argument("--device-count", type=int, default=512)
    p.add_argument("--remat", default="full", choices=["none", "dots", "full"])
    p.add_argument("--act-shard", default="none", choices=["none", "tp", "tp_sp"])
    p.add_argument("--microbatch", type=int, default=1)
    p.add_argument("--unroll-decode", action="store_true")
    p.add_argument("--compute-dtype", default="bfloat16")
    p.add_argument("--rules", default="default",
                   help="sharding rule preset (default|opt, see dist.sharding)")
    p.add_argument("--out", default="benchmarks/results/dryrun")
    p.add_argument("--tag", default="baseline")
    p.add_argument("--print-hlo", action="store_true")
    return p.parse_args(argv)


args = _parse_args()
if args.device_count != 512:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.device_count}")

import jax  # noqa: E402  (device count now locked)
import jax.numpy as jnp  # noqa: E402

from repro.configs import all_arch_names, get_config  # noqa: E402
from repro.dist import sharding as SH  # noqa: E402
from repro.launch import mesh as MESH  # noqa: E402
from repro.launch import specs as SPECS  # noqa: E402
from repro.models import get_model  # noqa: E402
from repro.train.step import abstract_state, make_serve_fns, make_train_step  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", ".."))
from benchmarks import hlo_analysis  # noqa: E402  (trip-count-aware costs)

# HLO dtype widths for collective-byte accounting
_DT_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
             "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
             "f64": 8, "c64": 8, "c128": 16}
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-buffer bytes of collective ops in the per-device HLO."""
    out = {op: 0 for op in _COLL_OPS}
    for line in hlo_text.splitlines():
        for op in _COLL_OPS:
            tok = f" {op}("
            tok_start = f" {op}-start("
            if (tok in line or tok_start in line) and f"{op}-done" not in line:
                head = line.split(tok_start if tok_start in line else tok)[0]
                for dt, dims in _SHAPE_RE.findall(head):
                    if dt not in _DT_BYTES:
                        continue
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    out[op] += n * _DT_BYTES[dt]
                break
    out["total"] = sum(out[op] for op in _COLL_OPS)
    return out


def _rules_preset(name: str):
    if name == "default":
        return None
    raise ValueError(name)


def build_cell(cfg, shape_name, mesh, *, remat, compute_dtype,
               act_shard="none", microbatch=1, unroll_decode=False):
    """Returns (jitted, example_args) for one cell, or raises."""
    kind, specs = SPECS.input_specs(cfg, shape_name)
    gdep = MESH.batch_shard_count(mesh)
    overrides = dict(attn_impl="xla", ssd_impl="xla", remat=remat,
                     compute_dtype=compute_dtype, act_shard=act_shard,
                     scan_layers_decode=not unroll_decode)
    if cfg.family == "moe":
        _, seq, batch, _ = SPECS.get_shape(cfg, shape_name)
        tokens = batch * (seq if kind == "train" or kind == "prefill" else 1)
        if kind == "decode":
            tokens = batch
        overrides["moe_groups"] = gdep if tokens % gdep == 0 else 1
    cfg = cfg.replace(**overrides)
    kind, specs = SPECS.input_specs(cfg, shape_name)  # re-spec with overrides

    if kind == "train":
        state, state_axes = abstract_state(cfg)
        state_sh = SH.tree_shardings(state, state_axes, mesh)
        batch_sh = SH.tree_shardings(specs["batch"],
                                     SH.batch_axes_for(specs["batch"]), mesh)
        step = make_train_step(cfg, microbatch=microbatch)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        return jitted, (state, specs["batch"]), cfg

    # serving cells: inference weights in the compute dtype (bf16), sharded
    # with FSDP over data AS WELL as TP — big models don't fit per-chip
    # otherwise; the per-layer weight all-gather is the usual latency trade.
    cfg = cfg.replace(param_dtype=compute_dtype)
    kind, specs = SPECS.input_specs(cfg, shape_name)
    serve_rules = dict(SH.DEFAULT_RULES)
    model = get_model(cfg)
    params = jax.eval_shape(lambda k: model.init(k, cfg)[0], jax.random.PRNGKey(0))
    params_sh = SH.tree_shardings(params, model.axes(cfg), mesh, serve_rules)
    batch_sh = SH.tree_shardings(specs["batch"],
                                 SH.batch_axes_for(specs["batch"]), mesh,
                                 serve_rules)
    cache_sh = SH.tree_shardings(specs["cache"],
                                 SH.cache_axes_for(specs["cache"]), mesh,
                                 serve_rules)
    prefill_step, decode_step = make_serve_fns(cfg)
    fn = prefill_step if kind == "prefill" else decode_step
    jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh, cache_sh),
                     out_shardings=(None, cache_sh),
                     donate_argnums=(2,))
    return jitted, (params, specs["batch"], specs["cache"]), cfg


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, out_dir, tag,
             remat, compute_dtype, mesh_shape=None, print_hlo=False,
             act_shard="none", microbatch=1, unroll_decode=False):
    cfg = get_config(arch)
    ok, reason = SPECS.shape_applicable(cfg, shape_name)
    cell_id = f"{arch}__{shape_name}__{mesh_kind}"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
           "remat": remat, "compute_dtype": compute_dtype,
           "act_shard": act_shard, "microbatch": microbatch,
           "unroll_decode": unroll_decode}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        _emit(out_dir, tag, cell_id, rec)
        print(f"[dryrun] {cell_id}: SKIPPED ({reason})")
        return rec

    if mesh_kind == "custom":
        shape = tuple(int(x) for x in mesh_shape.split(","))
        names = ("pod", "data", "model")[-len(shape):]
        mesh = MESH.make_mesh(shape, names)
    else:
        mesh = MESH.make_production_mesh(multi_pod=(mesh_kind == "multi"))

    t0 = time.time()
    with mesh, SH.use_mesh_rules(mesh):
        jitted, cell_args, cfg_used = build_cell(
            cfg, shape_name, mesh, remat=remat, compute_dtype=compute_dtype,
            act_shard=act_shard, microbatch=microbatch,
            unroll_decode=unroll_decode)
        lowered = jitted.lower(*cell_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = None
    try:
        m = compiled.memory_analysis()
        print(m)  # proves it fits (per-device bytes)
        mem = {k: int(getattr(m, k)) for k in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes") if hasattr(m, k)}
    except Exception as e:  # CPU backend may not implement it
        mem = {"error": str(e)}

    cost = {}
    try:
        c = compiled.cost_analysis()
        c = c[0] if isinstance(c, (list, tuple)) else c
        print({k: v for k, v in c.items()
               if k in ("flops", "bytes accessed", "utilization operand",)
               or k.startswith("bytes accessed")})
        cost = {k: float(v) for k, v in c.items()
                if isinstance(v, (int, float))}
    except Exception as e:
        cost = {"error": str(e)}

    hlo = compiled.as_text()
    coll_naive = collective_bytes(hlo)
    # trip-count-aware per-device costs (XLA's cost_analysis counts while
    # bodies once — see benchmarks/hlo_analysis.py)
    corrected = hlo_analysis.analyze(hlo)
    if print_hlo:
        print(hlo[:20000])

    rec.update({
        "status": "ok",
        "mesh_shape": {k: int(v) for k, v in mesh.shape.items()},
        "n_devices": int(mesh.size),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "flops": corrected["flops"],
        "hlo_bytes_est": corrected["bytes"],
        "collective_bytes": corrected["collective_bytes"],
        "flops_xla_raw": cost.get("flops"),
        "bytes_accessed_xla_raw": cost.get("bytes accessed"),
        "collective_bytes_raw": coll_naive,
        "cost_analysis": cost,
        "params": int(cfg_used.param_count()),
        "active_params": int(cfg_used.active_param_count()),
        "hlo_chars": len(hlo),
    })
    _emit(out_dir, tag, cell_id, rec)
    print(f"[dryrun] {cell_id}: OK  flops={rec['flops']:.3e} "
          f"coll={corrected['collective_bytes']['total']:.3e}B  "
          f"compile={t_compile:.1f}s")
    return rec


def _emit(out_dir, tag, cell_id, rec):
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{cell_id}__{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)


def main():
    archs = [args.arch] if args.arch else all_arch_names()
    fails = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = [args.shape] if args.shape else [s[0] for s in cfg.shapes]
        for shape_name in shapes:
            try:
                run_cell(arch, shape_name, args.mesh, out_dir=args.out,
                         tag=args.tag, remat=args.remat,
                         compute_dtype=args.compute_dtype,
                         mesh_shape=args.mesh_shape,
                         print_hlo=args.print_hlo,
                         act_shard=args.act_shard,
                         microbatch=args.microbatch,
                         unroll_decode=args.unroll_decode)
            except Exception as e:
                fails.append((arch, shape_name, repr(e)))
                print(f"[dryrun] {arch}/{shape_name}: FAIL {e!r}", file=sys.stderr)
    if fails:
        print(f"[dryrun] {len(fails)} FAILURES:", file=sys.stderr)
        for f in fails:
            print("  ", f, file=sys.stderr)
        sys.exit(1)
    print("[dryrun] all cells OK")


if __name__ == "__main__":
    main()
