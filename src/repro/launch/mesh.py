"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run forces 512 host devices via XLA_FLAGS before any import).

Mesh shapes: single pod = (data=16, model=16) — 256 chips (one v5e pod);
multi-pod adds an outer pure-DP "pod" axis = (pod=2, data=16, model=16).
The same logical-axis rule table resolves model configs onto either mesh
(the cluster-scale VLA contract, DESIGN.md §2).
"""

from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (2,2) on 4 forced devices)."""
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(f"mesh {shape} needs {need} devices, have {len(devs)}")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def batch_shard_count(mesh) -> int:
    """Number of ways the batch/token axis is sharded (pod x data)."""
    n = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            n *= mesh.shape[ax]
    return n
