"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run forces 512 host devices via XLA_FLAGS before any import).

Mesh shapes: single pod = (data=16, model=16) — 256 chips (one v5e pod);
multi-pod adds an outer pure-DP "pod" axis = (pod=2, data=16, model=16).
The same logical-axis rule table resolves model configs onto either mesh
(the cluster-scale VLA contract, DESIGN.md §2).
"""

from __future__ import annotations

import math
import os
import warnings

import jax


def force_host_devices(n: int) -> None:
    """Force ``n`` host-platform XLA devices (the multi-device-CPU testing
    pattern).  MUST run before jax initializes its backend — call it first
    thing in main(), before any jax array/device touch.  No-op when n <= 1
    or the flag is already set (e.g. by the CI job's environment)."""
    if n <= 1 or "--xla_force_host_platform_device_count" in os.environ.get(
            "XLA_FLAGS", ""):
        return
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count={n}")


def parse_mesh(spec: str) -> tuple[int, int]:
    """Parse a ``--mesh DxM`` spec ("4x2" -> (4, 2)): data axis x model axis."""
    try:
        d, m = spec.lower().split("x")
        d, m = int(d), int(m)
    except ValueError:
        raise ValueError(f"mesh spec {spec!r} is not DxM (e.g. '4x2')")
    if d < 1 or m < 1:
        raise ValueError(f"mesh spec {spec!r} must have positive axes")
    return d, m


def make_production_mesh(*, multi_pod: bool = False):
    """The full-scale mesh — or, on a dev box with fewer devices, the largest
    mesh the available devices support (axes halved largest-first, with a
    warning), so ``launch/serve.py --mesh`` runs anywhere the tests do."""
    shape = [2, 16, 16] if multi_pod else [16, 16]
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    devs = jax.devices()
    if len(devs) < math.prod(shape):
        want = math.prod(shape)
        while math.prod(shape) > len(devs):
            i = max(range(len(shape)), key=lambda j: shape[j])
            if shape[i] == 1:
                break
            shape[i] //= 2
        warnings.warn(
            f"{want}-device production mesh degraded to {tuple(shape)} over "
            f"{axes} ({len(devs)} devices available; force more with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={want})",
            RuntimeWarning, stacklevel=2)
    need = math.prod(shape)
    return jax.make_mesh(tuple(shape), axes, devices=devs[:need])


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (2,2) on 4 forced devices)."""
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(f"mesh {shape} needs {need} devices, have {len(devs)}")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def batch_shard_count(mesh) -> int:
    """Number of ways the batch/token axis is sharded (pod x data)."""
    n = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            n *= mesh.shape[ax]
    return n
