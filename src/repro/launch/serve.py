"""Serving launcher: bring up a ServeEngine for an arch (reduced dims on CPU)
and run a batch of ragged requests through it.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm_3b --reduce
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import all_arch_names, get_config
from repro.models import get_model
from repro.serve import ServeEngine

from .train import REDUCE


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b", choices=all_arch_names())
    ap.add_argument("--reduce", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    over = dict(REDUCE)
    if cfg.family in ("ssm", "hybrid"):
        over.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
    if cfg.family == "moe":
        over.update(n_experts=8, top_k=2, d_ff_dense=128)
    if cfg.family == "encdec":
        over.update(n_enc_layers=2, n_dec_layers=2)
    if cfg.family == "hybrid":
        over.update(n_layers=5, shared_attn_period=2)
    if cfg.cross_attn_group:
        over.update(n_layers=10)
    cfg = cfg.replace(**over)

    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(
        rng.randint(1, cfg.vocab_size, (args.batch, args.prompt_len))),
        "lens": jnp.asarray(rng.randint(4, args.prompt_len + 1, args.batch))}
    if cfg.family == "dense" and cfg.cross_attn_group:
        batch["cross_emb"] = jnp.asarray(
            rng.randn(args.batch, cfg.n_cross_tokens, cfg.d_model)
            .astype(np.float32))
    if cfg.family == "encdec":
        batch["src_emb"] = jnp.asarray(
            rng.randn(args.batch, args.prompt_len, cfg.d_model)
            .astype(np.float32))
        batch["src_lens"] = jnp.full((args.batch,), args.prompt_len, jnp.int32)

    eng = ServeEngine(cfg, params, max_new_tokens=args.max_new, stop_token=7)
    res = eng.generate(batch)
    for i in range(args.batch):
        n = int(res["n_generated"][i])
        print(f"req{i} len={int(batch['lens'][i]):2d} -> "
              f"{res['tokens'][i, :n].tolist()}")


if __name__ == "__main__":
    main()
