"""Serving launcher: bring up a ServeEngine for an arch (reduced dims on CPU)
and push a stream of ragged requests through the continuous-batching
scheduler (default), or a single static batch with --static.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm_3b --reduce
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import all_arch_names, get_config
from repro.dist import collectives as C
from repro.models import get_model
from repro.obs import Obs, Tracer
from repro.serve import (
    ChaosConfig,
    ChaosMonkey,
    ContinuousBatchingScheduler,
    SamplingParams,
    ServeEngine,
)

from .mesh import force_host_devices, make_mesh, parse_mesh
from .train import REDUCE


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b", choices=all_arch_names())
    ap.add_argument("--reduce", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4,
                    help="lane capacity (scheduler) / batch size (--static)")
    ap.add_argument("--requests", type=int, default=10,
                    help="number of streamed requests (scheduler mode)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=4,
                    help="decode steps between admission opportunities")
    ap.add_argument("--compact-threshold", type=float, default=0.5)
    ap.add_argument("--page-size", type=int, default=None,
                    help="tokens per KV page; enables the paged cache "
                         "(admission gated on page availability)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="physical pages in the pool (default: the dense "
                         "footprint, capacity * pages-per-lane)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable prompt-prefix page sharing under --page-size")
    ap.add_argument("--page-dtype", choices=["int8", "fp8"], default=None,
                    help="quantized KV page pools: pages hold narrow elements "
                         "with per-(page, head, slot) f32 absmax scales, "
                         "dequantized inside the paged-attention gather "
                         "(requires --page-size)")
    ap.add_argument("--host-swap-pages", type=int, default=None,
                    help="host-side LRU swap store capacity in pages: shared-"
                         "prefix pages spill to host on eviction and page "
                         "back in on a later prompt hit — the cross-request "
                         "session cache (requires --page-size)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="split admission prefill into chunks of this many "
                         "tokens interleaved with decode rounds (long "
                         "prompts stop stalling resident lanes; dense/moe "
                         "families)")
    ap.add_argument("--paged-attn", choices=["native", "gather"],
                    default="native",
                    help="paged decode path: 'native' reads K/V through the "
                         "page table inside flash attention; 'gather' is the "
                         "reference oracle (dense view materialized per step)")
    ap.add_argument("--no-fused", action="store_true",
                    help="run the legacy multi-dispatch host loop instead of "
                         "the fused one-dispatch-per-round step program")
    ap.add_argument("--overlap", action="store_true",
                    help="async host loop: dispatch round N+1 before reading "
                         "round N (one blocking sync per round)")
    ap.add_argument("--src-len", type=int, default=None,
                    help="encdec: padded encoder memory length the scheduler "
                         "allocates caches for (default: --prompt-len)")
    ap.add_argument("--static", action="store_true",
                    help="one-shot ServeEngine.generate instead of scheduler")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded admission queue: submits past this many "
                         "queued requests are SHED (typed partial result) "
                         "instead of queueing unboundedly")
    ap.add_argument("--deadline-steps", type=float, default=None,
                    help="per-request completion deadline, in decode steps "
                         "after arrival: a request still decoding past it "
                         "retires with its partial output "
                         "(finish_reason='deadline')")
    ap.add_argument("--priority-every", type=int, default=0,
                    help="mark every Nth request priority 5 (0 disables): "
                         "under page/lane starvation high-priority arrivals "
                         "preempt a lower-priority lane and the victim later "
                         "resumes bit-exactly")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="install a deterministic ChaosMonkey with this "
                         "seed (requires --chaos-* rates below to do "
                         "anything)")
    ap.add_argument("--chaos-alloc-fail-rate", type=float, default=0.0,
                    help="probability each page allocation spuriously fails "
                         "(models transient pool pressure)")
    ap.add_argument("--chaos-cancel-rate", type=float, default=0.0,
                    help="per-round probability each live request is "
                         "cancelled (exercises every cancel branch)")
    ap.add_argument("--chaos-swap-corrupt-rate", type=float, default=0.0,
                    help="probability a host-swap insert is byte-flipped "
                         "after its CRC — the next hit must degrade to a "
                         "cold prefill, never serve corrupt K/V")
    ap.add_argument("--temperature", type=float, default=None,
                    help="enable per-request stochastic sampling at this "
                         "temperature (default: greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k vocab filtering (0 disables)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus filtering mass (1.0 disables)")
    ap.add_argument("--min-p", type=float, default=0.0,
                    help="min-p filtering (0 disables)")
    ap.add_argument("--repetition-penalty", type=float, default=1.0)
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="base sampling seed; request i uses seed+i, so "
                         "every stream is reproducible per request")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="mesh-sharded serving: data x model axes (e.g. 4x2 "
                         "= lanes over 4 ways, KV heads/MLP/experts over 2). "
                         "On a host-only box the device count is forced via "
                         "XLA_FLAGS; served tokens are byte-identical to the "
                         "unsharded loop")
    ap.add_argument("--psum", choices=list(C.PSUM_MODES), default="fast",
                    help="cross-device reduction ordering for shard_map-"
                         "level code: native all-reduce, or the "
                         "deterministic ordered (fadda) / pairwise (faddv) "
                         "collectives")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="record the serve run's round/request timeline and "
                         "export Chrome/Perfetto trace_event JSON to FILE "
                         "(open in ui.perfetto.dev); served tokens and "
                         "dispatch/sync counts are unchanged by tracing")
    ap.add_argument("--metrics", action="store_true",
                    help="print the obs registry snapshot (the flat "
                         "counter/percentile dict the serving bench records "
                         "per leg) after the run")
    ap.add_argument("--xla-annotations", action="store_true",
                    help="wrap dispatch-seam spans in jax.profiler."
                         "TraceAnnotation so a concurrently captured XLA "
                         "device profile interleaves with the host timeline")
    args = ap.parse_args()

    C.set_psum_mode(args.psum)
    obs = Obs(tracer=Tracer() if args.trace_out else None,
              xla_annotations=args.xla_annotations)
    if args.metrics or args.trace_out:
        C.set_obs(obs)
    mesh = None
    if args.mesh is not None:
        d, m = parse_mesh(args.mesh)
        # must precede ANY backend touch (jax initializes devices lazily)
        force_host_devices(d * m)
        mesh = make_mesh((d, m), ("data", "model"))

    def _sampling(i: int):
        """Per-request SamplingParams (None = greedy) for request index i."""
        if args.temperature is None:
            return None
        return SamplingParams(temperature=args.temperature, top_k=args.top_k,
                              top_p=args.top_p, min_p=args.min_p,
                              repetition_penalty=args.repetition_penalty,
                              seed=args.sample_seed + i, greedy=False)

    cfg = get_config(args.arch)
    over = dict(REDUCE)
    if cfg.family in ("ssm", "hybrid"):
        over.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
    if cfg.family == "moe":
        over.update(n_experts=8, top_k=2, d_ff_dense=128)
    if cfg.family == "encdec":
        over.update(n_enc_layers=2, n_dec_layers=2)
    if cfg.family == "hybrid":
        over.update(n_layers=5, shared_attn_period=2)
    if cfg.cross_attn_group:
        over.update(n_layers=10)
    cfg = cfg.replace(**over)

    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(
        rng.randint(1, cfg.vocab_size, (args.batch, args.prompt_len))),
        "lens": jnp.asarray(rng.randint(4, args.prompt_len + 1, args.batch))}
    if cfg.family == "dense" and cfg.cross_attn_group:
        batch["cross_emb"] = jnp.asarray(
            rng.randn(args.batch, cfg.n_cross_tokens, cfg.d_model)
            .astype(np.float32))
    if cfg.family == "encdec":
        batch["src_emb"] = jnp.asarray(
            rng.randn(args.batch, args.prompt_len, cfg.d_model)
            .astype(np.float32))
        batch["src_lens"] = jnp.full((args.batch,), args.prompt_len, jnp.int32)

    if args.page_dtype is not None and args.page_size is None:
        ap.error("--page-dtype requires --page-size (quantization lives in "
                 "the page pools)")
    if args.host_swap_pages is not None and args.page_size is None:
        ap.error("--host-swap-pages requires --page-size (the swap tier "
                 "moves pages)")
    eng = ServeEngine(cfg, params, max_new_tokens=args.max_new, stop_token=7,
                      paged_attn=args.paged_attn, mesh=mesh,
                      page_dtype=args.page_dtype, obs=obs)
    if args.static or cfg.cross_attn_group:
        # vlm cross_emb extras are per-batch, not yet per-request: static path
        res = eng.generate(batch, sampling=[_sampling(i)
                                            for i in range(args.batch)])
        for i in range(args.batch):
            n = int(res["n_generated"][i])
            print(f"req{i} len={int(batch['lens'][i]):2d} -> "
                  f"{res['tokens'][i, :n].tolist()}")
        _finish_obs(args, obs)
        return

    # ---- continuous batching: stream requests through the lane vector ----
    max_len = args.prompt_len + args.max_new
    src_len = ((args.src_len or args.prompt_len)
               if cfg.family == "encdec" else None)
    sched = ContinuousBatchingScheduler(
        eng, capacity=args.batch, max_len=max_len, chunk=args.chunk,
        compact_threshold=args.compact_threshold, page_size=args.page_size,
        pool_pages=args.pool_pages,
        prefix_sharing=not args.no_prefix_sharing,
        host_swap_pages=args.host_swap_pages,
        prefill_chunk=args.prefill_chunk,
        fused=not args.no_fused, overlap=args.overlap, src_len=src_len,
        max_queue=args.max_queue, obs=obs)
    monkey = None
    if args.chaos_seed is not None:
        monkey = ChaosMonkey(ChaosConfig(
            seed=args.chaos_seed,
            alloc_fail_rate=args.chaos_alloc_fail_rate,
            cancel_rate=args.chaos_cancel_rate,
            swap_corrupt_rate=args.chaos_swap_corrupt_rate)).install(sched)
    rid_len = {}
    for i in range(args.requests):
        plen = int(rng.randint(4, args.prompt_len + 1))
        extras = None
        if cfg.family == "encdec":
            sl = int(rng.randint(2, src_len + 1))
            extras = {"src_emb": rng.randn(sl, cfg.d_model)
                      .astype(np.float32)}
        prio = (5 if args.priority_every and i % args.priority_every == 0
                else 0)
        rid = sched.submit(rng.randint(1, cfg.vocab_size, plen),
                           sampling=_sampling(i), extras=extras,
                           priority=prio,
                           deadline=(args.deadline_steps
                                     if args.deadline_steps else None))
        rid_len[rid] = plen
    results = monkey.run(sched) if monkey else sched.run()
    for rid in sorted(results):
        r = results[rid]
        print(f"req{rid} len={rid_len[rid]:2d} "
              f"[{r['finish_reason'].value}] -> "
              f"{r['tokens'].tolist()}")
    occ = sched.stats["occupancy_trace"]
    print(f"[scheduler] rounds={sched.stats['steps']} "
          f"dispatches={sched.stats['dispatches']} "
          f"host syncs={sched.stats['host_syncs']} "
          f"compactions={sched.stats['compactions']} "
          f"mean occupancy={sum(occ) / max(len(occ), 1):.2f}"
          + (f"  prefill chunks={sched.stats['prefill_chunks']}"
             if args.prefill_chunk else ""))
    st = sched.stats
    if (st["preemptions"] or st["cancelled"] or st["shed"]
            or st["deadline_misses"] or monkey):
        print(f"[robustness] preemptions={st['preemptions']} "
              f"(pages back in={st['resume_page_ins']})  "
              f"cancelled={st['cancelled']}  shed={st['shed']}  "
              f"deadline misses={st['deadline_misses']}"
              + (f"  [chaos seed={args.chaos_seed}: "
                 f"alloc fails={monkey.alloc_failures} "
                 f"cancels={monkey.cancels} "
                 f"corruptions={monkey.corruptions}]" if monkey else ""))
    if args.page_size is not None:
        pocc = sched.stats["page_occupancy_trace"]
        print(f"[paged] pool={sched.pool_pages} pages "
              f"(page_size={args.page_size})  "
              f"mean pool occupancy={sum(pocc) / max(len(pocc), 1):.2f}  "
              f"prefix hits={sched.stats['prefix_hits']} "
              f"({sched.stats['prefix_hit_tokens']} tokens skipped)  "
              f"page waits={sched.stats['page_waits']}"
              + (f"  page_dtype={args.page_dtype}" if args.page_dtype
                 else ""))
        if args.host_swap_pages:
            print(f"[swap] session hits={sched.stats['session_hits']} "
                  f"({sched.stats['session_hit_tokens']} tokens skipped)  "
                  f"out={sched.stats['swap_out_pages']} "
                  f"in={sched.stats['swap_in_pages']} pages  "
                  f"store={len(sched.host_swap)}/{args.host_swap_pages}  "
                  f"checksum failures="
                  f"{sched.stats['swap_checksum_failures']}")
    _finish_obs(args, obs)


def _finish_obs(args, obs):
    """Export the trace / print the metrics snapshot per the CLI flags."""
    if args.trace_out:
        n = obs.export(args.trace_out)
        print(f"[obs] wrote {n} trace events to {args.trace_out} "
              "(open in ui.perfetto.dev or chrome://tracing)")
    if args.metrics:
        import json
        print("[obs] " + json.dumps(obs.metrics.snapshot(), indent=2,
                                    sort_keys=True))


if __name__ == "__main__":
    main()
