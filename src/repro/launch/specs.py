"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

Follows the task spec: weak-type-correct, shardable, zero allocation.  The
modality frontends ([vlm] image patches, [audio] speech frames) are STUBS —
``input_specs`` provides precomputed embeddings of the right shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import get_model

I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def get_shape(cfg, shape_name: str):
    for (name, seq, batch, kind) in cfg.shapes:
        if name == shape_name:
            return name, int(seq), int(batch), kind
    raise KeyError(f"{cfg.name} has no shape {shape_name!r}; "
                   f"available: {[s[0] for s in cfg.shapes]}")


def shape_applicable(cfg, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason) — long_500k is skipped for full-attention archs."""
    _, _, _, kind = get_shape(cfg, shape_name)
    if kind == "long" and cfg.skip_long_context:
        return False, ("skipped: full-attention arch — 512k decode cache is "
                       "quadratic-history; run for ssm/hybrid only (DESIGN.md §4)")
    return True, ""


def train_batch_specs(cfg, seq: int, batch: int):
    emb_dt = jnp.dtype(cfg.compute_dtype)
    specs = {"tokens": _sds((batch, seq), I32),
             "labels": _sds((batch, seq), I32)}
    if cfg.family == "dense" and cfg.cross_attn_group:
        specs["cross_emb"] = _sds((batch, cfg.n_cross_tokens, cfg.d_model), emb_dt)
    if cfg.family == "encdec":
        specs["src_emb"] = _sds((batch, seq, cfg.d_model), emb_dt)
        specs["src_lens"] = _sds((batch,), I32)
    return specs


def prefill_batch_specs(cfg, seq: int, batch: int):
    specs = train_batch_specs(cfg, seq, batch)
    del specs["labels"]
    specs["lens"] = _sds((batch,), I32)
    return specs


def decode_batch_specs(cfg, batch: int):
    return {"token": _sds((batch, 1), I32)}


def cache_specs(cfg, batch: int, max_len: int):
    model = get_model(cfg)
    if cfg.family == "encdec":
        return jax.eval_shape(
            lambda: model.make_cache(cfg, batch, max_len, src_len=max_len))
    return jax.eval_shape(lambda: model.make_cache(cfg, batch, max_len))


def input_specs(cfg, shape_name: str):
    """Returns (step_kind, specs dict) for the cell.

    train  -> {"batch": ...}
    prefill-> {"batch": ..., "cache": ...}
    decode -> {"batch": ..., "cache": ...}   (cache length = seq_len)
    """
    _, seq, batch, kind = get_shape(cfg, shape_name)
    if kind == "train":
        return "train", {"batch": train_batch_specs(cfg, seq, batch)}
    if kind == "prefill":
        return "prefill", {"batch": prefill_batch_specs(cfg, seq, batch),
                           "cache": cache_specs(cfg, batch, seq)}
    if kind in ("decode", "long"):
        return "decode", {"batch": decode_batch_specs(cfg, batch),
                          "cache": cache_specs(cfg, batch, seq)}
    raise ValueError(kind)
