"""Training launcher: real training for small/medium runs on the local
devices (see dryrun.py for the 80-cell mesh-scale lowering driver).

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 50 --batch 8 --seq 128 --reduce

XLA latency-hiding flags for real TPU runs (compute/comm overlap):
    LIBTPU_INIT_ARGS="--xla_tpu_enable_async_collective_fusion=true
    --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true
    --xla_enable_async_all_gather=true"
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import all_arch_names, get_config
from repro.data import SyntheticLM
from repro.runtime import FaultTolerantLoop
from repro.train.step import init_state, make_train_step

REDUCE = dict(n_layers=2, d_model=64, d_ff=128, vocab_size=256, n_heads=4,
              n_kv_heads=2, head_dim=16, n_cross_tokens=16,
              param_dtype="float32", compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2_130m", choices=all_arch_names())
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduce", action="store_true",
                    help="shrink dims for CPU (keeps family/topology)")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        over = dict(REDUCE)
        if cfg.family in ("ssm", "hybrid"):
            over.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
        if cfg.family == "moe":
            over.update(n_experts=8, top_k=2,
                        d_ff_dense=128 if cfg.first_k_dense else None)
        if cfg.family == "encdec":
            over.update(n_enc_layers=2, n_dec_layers=2)
        if cfg.family == "hybrid":
            over.update(n_layers=5, shared_attn_period=2)
        if cfg.cross_attn_group:
            over.update(n_layers=10)
        cfg = cfg.replace(**{k: v for k, v in over.items() if v is not None})
    print(f"arch={cfg.name} family={cfg.family} params={cfg.param_count():.3e}")

    state, _ = init_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, microbatch=args.microbatch),
                   donate_argnums=(0,))
    data = SyntheticLM(cfg.vocab_size, args.seq, seed=0)

    import numpy as np

    def batch_fn(s):
        tokens, labels, lens = data.batch(s, args.batch)
        batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels),
                 "lens": jnp.asarray(lens)}
        if cfg.family == "dense" and cfg.cross_attn_group:
            batch["cross_emb"] = jnp.asarray(
                np.random.RandomState(s).randn(
                    args.batch, cfg.n_cross_tokens, cfg.d_model)
                .astype(np.float32))
        if cfg.family == "encdec":
            batch["src_emb"] = jnp.asarray(
                np.random.RandomState(s).randn(args.batch, args.seq,
                                               cfg.d_model).astype(np.float32))
            batch["src_lens"] = jnp.full((args.batch,), args.seq, jnp.int32)
        return batch

    loop = FaultTolerantLoop(step, batch_fn, ckpt_dir=args.ckpt_dir,
                             save_every=10)
    state, hist = loop.run(state, args.steps, metrics_cb=lambda s, m: print(
        f"  step {s:3d} loss {float(m['loss']):.4f}") if s % 5 == 0 else None)
    print(f"loss {hist[0][1]:.4f} -> {hist[-1][1]:.4f}")


if __name__ == "__main__":
    main()
