"""Model zoo: pure-functional, scan-over-layers definitions for every
assigned architecture family (dense / moe / ssm / hybrid / encdec), all built
on the predicated attention + SSD kernels and the VLA core.
"""

import jax.numpy as jnp

from .config import ModelConfig  # noqa: F401


def get_model(cfg: "ModelConfig"):
    """Return the module implementing cfg.family's model API:
    init(key, cfg) -> (params, axes);
    train_logits(params, cfg, batch) -> (logits, aux);
    prefill(params, cfg, batch) -> (logits_last, cache);
    decode(params, cfg, batch, cache) -> (logits, cache);
    make_cache(cfg, batch_size, ...) -> cache pytree;
    cache_batch_axes(cfg) -> {cache key: request-lane axis}.
    """
    from . import dense, encdec, hybrid, moe, ssm
    return {
        "dense": dense,
        "moe": moe,
        "ssm": ssm,
        "hybrid": hybrid,
        "encdec": encdec,
    }[cfg.family]


# ---------------------------------------------------------------------------
# Cache lane interface (SVE §2.3.4 applied to request traffic)
#
# A decode cache is a dict of arrays, each with ONE request-lane axis declared
# by the family's ``cache_batch_axes(cfg)``.  The two operations below are the
# only ways the serving layer moves request state between lanes — pure index
# gathers/scatters, so lane compaction and slot refill are data movements the
# compiler can alias in place (no `jnp.where` over the full cache tree, no
# "first axis that matches B" guessing).
# ---------------------------------------------------------------------------

def gather_lanes(cfg, cache, lanes):
    """Permute/select request lanes of every cache array: out lane i takes the
    state of input lane ``lanes[i]`` (SVE ``compact``-style index gather).

    ``lanes`` may be shorter than the lane count (slicing a sub-batch out) or
    a full permutation (lane compaction).  jit-safe.
    """
    axes = get_model(cfg).cache_batch_axes(cfg)
    lanes = jnp.asarray(lanes, jnp.int32)
    return {k: jnp.take(v, lanes, axis=axes[k]) for k, v in cache.items()}


def slot_update(cfg, cache, lanes, sub_cache):
    """Write ``sub_cache`` (a cache whose lane count equals ``len(lanes)``)
    into ``cache`` at lane indices ``lanes`` via in-place ``.at[].set``
    scatters along each array's declared lane axis.

    This is the admission path of continuous batching: a freshly prefilled
    sub-batch splices into recycled lanes of the live cache.  jit-safe.
    """
    axes = get_model(cfg).cache_batch_axes(cfg)
    lanes = jnp.asarray(lanes, jnp.int32)
    out = dict(cache)
    for k, v in cache.items():
        ax = axes[k]
        idx = tuple([slice(None)] * ax + [lanes])
        out[k] = v.at[idx].set(sub_cache[k].astype(v.dtype))
    return out
