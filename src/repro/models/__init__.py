"""Model zoo: pure-functional, scan-over-layers definitions for every
assigned architecture family (dense / moe / ssm / hybrid / encdec), all built
on the predicated attention + SSD kernels and the VLA core.
"""

from .config import ModelConfig  # noqa: F401


def get_model(cfg: "ModelConfig"):
    """Return the module implementing cfg.family's model API:
    init(key, cfg) -> (params, axes);
    train_logits(params, cfg, batch) -> (logits, aux);
    prefill(params, cfg, batch) -> (logits_last, cache);
    decode(params, cfg, batch, cache) -> (logits, cache).
    """
    from . import dense, encdec, hybrid, moe, ssm
    return {
        "dense": dense,
        "moe": moe,
        "ssm": ssm,
        "hybrid": hybrid,
        "encdec": encdec,
    }[cfg.family]
