"""Model zoo: pure-functional, scan-over-layers definitions for every
assigned architecture family (dense / moe / ssm / hybrid / encdec), all built
on the predicated attention + SSD kernels and the VLA core.
"""

import jax.numpy as jnp

from repro.core import paging as PG

from .config import ModelConfig  # noqa: F401


def get_model(cfg: "ModelConfig"):
    """Return the module implementing cfg.family's model API:
    init(key, cfg) -> (params, axes);
    train_logits(params, cfg, batch) -> (logits, aux);
    prefill(params, cfg, batch) -> (logits_last, cache);
    decode(params, cfg, batch, cache) -> (logits, cache);
    make_cache(cfg, batch_size, ...) -> cache pytree;
    cache_batch_axes(cfg) -> {cache key: request-lane axis};
    paged_cache_spec(cfg) -> {KV cache key: leading layer-stack dims};
    make_paged_cache(cfg, batch_size, max_len, page_size=, pool_pages=).
    """
    from . import dense, encdec, hybrid, moe, ssm
    return {
        "dense": dense,
        "moe": moe,
        "ssm": ssm,
        "hybrid": hybrid,
        "encdec": encdec,
    }[cfg.family]


# ---------------------------------------------------------------------------
# Cache lane interface (SVE §2.3.4 applied to request traffic)
#
# A decode cache is a dict of arrays, each with ONE request-lane axis declared
# by the family's ``cache_batch_axes(cfg)``.  The two operations below are the
# only ways the serving layer moves request state between lanes — pure index
# gathers/scatters, so lane compaction and slot refill are data movements the
# compiler can alias in place (no `jnp.where` over the full cache tree, no
# "first axis that matches B" guessing).
# ---------------------------------------------------------------------------

def _lane_axes(cfg, cache):
    """Lane axis per cache key, paged-layout aware: page pools carry NO lane
    axis (lanes address them only through the page table), the page table's
    lane axis is 0."""
    axes = get_model(cfg).cache_batch_axes(cfg)
    if "page_table" not in cache:
        return axes
    out = {k: ax for k, ax in axes.items() if k in cache}
    out["page_table"] = 0
    return out


def gather_lanes(cfg, cache, lanes):
    """Permute/select request lanes of every cache array: out lane i takes the
    state of input lane ``lanes[i]`` (SVE ``compact``-style index gather).

    ``lanes`` may be shorter than the lane count (slicing a sub-batch out) or
    a full permutation (lane compaction).  On a paged cache the pools pass
    through untouched — moving a lane moves its page-table ROW, never its
    pages, so compaction is O(n_pages) instead of O(cache).  jit-safe.
    """
    axes = _lane_axes(cfg, cache)
    lanes = jnp.asarray(lanes, jnp.int32)
    return {k: (jnp.take(v, lanes, axis=axes[k]) if k in axes else v)
            for k, v in cache.items()}


def merge_lanes(cfg, cache, lanes, sub_cache):
    """Write a decode burst's narrowed ``sub_cache`` back into ``cache``:
    lane-axis arrays splice at ``lanes`` (slot_update), while arrays WITHOUT
    a lane axis — the shared page pools — are taken from ``sub_cache``
    wholesale, because the narrowed burst scatter-stored its new tokens into
    them through the (narrowed) page table.  jit-safe."""
    axes = _lane_axes(cfg, cache)
    out = slot_update(cfg, cache, lanes, sub_cache)
    for k, v in sub_cache.items():
        if k in out and k not in axes:
            out[k] = v
    return out


def slot_update(cfg, cache, lanes, sub_cache):
    """Write ``sub_cache`` (a cache whose lane count equals ``len(lanes)``)
    into ``cache`` at lane indices ``lanes`` via in-place ``.at[].set``
    scatters along each array's declared lane axis.

    This is the admission path of continuous batching: a freshly prefilled
    sub-batch splices into recycled lanes of the live cache.  Keys without a
    lane axis (page pools) and keys missing from ``sub_cache`` (paged
    admission updates KV through page copies, not lane scatters) pass
    through.  jit-safe.
    """
    axes = _lane_axes(cfg, cache)
    lanes = jnp.asarray(lanes, jnp.int32)
    out = dict(cache)
    for k, v in cache.items():
        if k not in axes or k not in sub_cache:
            continue
        ax = axes[k]
        idx = tuple([slice(None)] * ax + [lanes])
        out[k] = v.at[idx].set(sub_cache[k].astype(v.dtype))
    return out


# ---------------------------------------------------------------------------
# Paged cache layout (SVE §2.3.3 gather/scatter applied to KV memory)
#
# A paged cache replaces each KV tensor's per-lane (max_len) axis with a
# shared page POOL (``<key>_pages``: lead + (P, Hkv, page_size, D)) plus one
# per-lane int32 ``page_table`` (B, n_pages) shared by every pool.  The dense
# layout is the degenerate case page_size == max_len with one private page per
# lane.  Two bridges connect the layouts:
#
#   * ``paged_view``     — gather-load the dense logical view (bitwise equal
#                          to the dense cache the model functions expect);
#   * ``paged_writeback``— scatter-store a decode step's single-token writes
#                          back into the pools.
#
# Both are pure index gathers/scatters, jit-safe, and run INSIDE the serving
# engine's compiled decode loop.
# ---------------------------------------------------------------------------

def is_paged(cache) -> bool:
    return isinstance(cache, dict) and "page_table" in cache


def is_quantized(cache) -> bool:
    """True when the paged cache stores pools NARROW (int8/fp8) with per-slot
    scale pools riding alongside under ``<key>_pages_scale`` — SVE §2.3.3
    extending/truncating loads applied to KV memory."""
    return isinstance(cache, dict) and any(
        k.endswith("_pages_scale") for k in cache)


def paged_decode_ok(cfg) -> bool:
    """True when cfg's family decode() consumes a paged cache NATIVELY:
    flash attention reads K/V through the page table and each layer
    scatter-stores its new token straight into the lane's tail page — no
    dense-view materialization on the decode hot path."""
    fn = getattr(get_model(cfg), "paged_decode_ok", None)
    return bool(fn and fn(cfg))


def chunked_prefill_ok(cfg) -> bool:
    """True when cfg's family prefill() supports per-row ``pos0`` start
    offsets with all cross-chunk state carried in the cache — the property
    that makes splitting one prompt's prefill into chunks bit-identical to
    prefilling it whole.  All five families now qualify: dense/moe keep
    everything in the KV cache, ssm/hybrid resume the conv taps + SSM state,
    encdec caches per-layer cross K/V on the first chunk."""
    return bool(getattr(get_model(cfg), "CHUNKED_PREFILL_OK", False))


def lane_independent_decode(cfg) -> bool:
    """True when cfg's family decode() treats request lanes independently —
    no cross-lane coupling anywhere in the step — so running a decode burst
    over any lane PREFIX produces bit-identical per-lane results.  This is
    what lets the fused serve step narrow its burst to the occupied pow2
    lane bucket (SVE predicate-narrowing applied to the batch axis).  MoE
    does not qualify: expert capacity is shared across the batch, so
    dropping (dead) lanes changes which tokens overflow an expert buffer."""
    return bool(getattr(get_model(cfg), "LANE_INDEPENDENT_DECODE", False))


def chunked_prefill_granularity(cfg) -> int:
    """Alignment (in tokens) chunk boundaries must respect for chunked
    prefill to stay bit-identical to whole-prompt prefill.  1 for attention
    families (position-exact at any split); ssm/hybrid require boundaries on
    multiples of ``ssm_chunk`` so the resumed SSD scan replays the same
    chunk_step sequence as the unchunked scan."""
    fn = getattr(get_model(cfg), "chunked_prefill_granularity", None)
    return int(fn(cfg)) if fn else 1


def to_paged(cfg, cache, *, page_size: int, pool_pages=None, page_dtype=None):
    """Convert a DENSE cache to the paged layout with identity page tables
    (lane b's logical block j lives in physical page ``b * n_pages + j``).

    The inverse of ``paged_view`` up to pool padding: gathering the result
    reproduces the dense cache bit-exactly.  Used by the one-shot engine to
    serve families the scheduler does not manage (encdec, vlm) through the
    native paged decode path, and by tests to build paged caches without a
    scheduler.  Token axes are zero-padded up to a page multiple.

    With ``page_dtype`` the pools store NARROW: each token row truncates to
    int8/fp8 against its absmax scale (``<key>_pages_scale``), and the
    round trip through ``paged_view`` is identity up to quantization error.
    """
    spec = get_model(cfg).paged_cache_spec(cfg)
    if not spec:
        raise ValueError(f"family '{cfg.family}' has no pageable KV state")
    key0, lead0 = next(iter(spec.items()))
    b = cache[key0].shape[len(lead0)]
    max_len = cache[key0].shape[len(lead0) + 2]
    n_pages = PG.pages_needed(max_len, page_size)
    need = b * n_pages
    pool_pages = need if pool_pages is None else pool_pages
    if pool_pages < need:
        raise ValueError(f"pool_pages={pool_pages} < {need} needed for the "
                         f"identity layout ({b} lanes x {n_pages} pages)")
    qdt = PG.resolve_page_dtype(page_dtype)
    out = {k: v for k, v in cache.items() if k not in spec}
    for key, lead in spec.items():
        nl = len(lead)
        v = cache[key]                               # lead+(B,Hkv,S,D)
        pad = n_pages * page_size - v.shape[nl + 2]
        if pad:
            widths = [(0, 0)] * v.ndim
            widths[nl + 2] = (0, pad)
            v = jnp.pad(v, widths)
        hkv, d = v.shape[nl + 1], v.shape[nl + 3]
        v = v.reshape(v.shape[:nl] + (b, hkv, n_pages, page_size, d))
        v = jnp.moveaxis(v, nl + 2, nl + 1)          # lead+(B,n,Hkv,ps,D)
        v = v.reshape(v.shape[:nl] + (need, hkv, page_size, d))
        if pool_pages > need:
            widths = [(0, 0)] * v.ndim
            widths[nl] = (0, pool_pages - need)
            v = jnp.pad(v, widths)
        if qdt is not None:
            v, sc = PG.quantize_block(v, qdt)        # truncating store
            out[key + "_pages_scale"] = sc
        out[key + "_pages"] = v
    out["page_table"] = (jnp.arange(b, dtype=jnp.int32)[:, None] * n_pages
                         + jnp.arange(n_pages, dtype=jnp.int32)[None, :])
    return out


def paged_view(cfg, cache):
    """Materialize the dense logical view of a paged cache through the page
    table (SVE gather-load).  Non-paged per-lane entries pass through.  On a
    quantized cache the gather widens (dequantizes) the pools, so the view is
    always full precision."""
    spec = get_model(cfg).paged_cache_spec(cfg)
    table = cache["page_table"]
    out = {k: v for k, v in cache.items()
           if k != "page_table" and not k.endswith("_pages")
           and not k.endswith("_pages_scale")}
    for key, lead in spec.items():
        out[key] = PG.gather_pages(cache[key + "_pages"], table,
                                   n_lead=len(lead),
                                   scale=cache.get(key + "_pages_scale"))
    return out


def paged_writeback(cfg, cache, view, pos):
    """Scatter the ONE token a decode step wrote at per-lane position ``pos``
    from the dense view back into the page pools, and carry the updated
    per-lane state (pos, conv/ssm state, ...) across.

    ``pos`` is the position written (the lane's length BEFORE the step).
    Writes land in the lane's tail page, which the allocator guarantees is
    privately owned — shared prefix pages are immutable.
    """
    spec = get_model(cfg).paged_cache_spec(cfg)
    table = cache["page_table"]
    n_pages = table.shape[1]
    out = dict(cache)
    pos = jnp.asarray(pos, jnp.int32)
    page_col = jnp.clip(pos // _page_size_of(cfg, cache), 0, n_pages - 1)
    page_ids = jnp.take_along_axis(table, page_col[:, None], axis=1)[:, 0]
    offsets = pos % _page_size_of(cfg, cache)
    for key, lead in spec.items():
        v = view[key]                                 # lead+(B,Hkv,S,D)
        s = v.shape[-2]
        idx = jnp.clip(pos, 0, s - 1).reshape((1,) * len(lead) + (-1, 1, 1, 1))
        tok = jnp.take_along_axis(v, idx, axis=-2)[..., 0, :]   # lead+(B,Hkv,D)
        sc = cache.get(key + "_pages_scale")
        if sc is not None:                            # truncating store
            out[key + "_pages"], out[key + "_pages_scale"] = PG.scatter_page_q(
                cache[key + "_pages"], sc, page_ids, offsets, tok,
                n_lead=len(lead))
        else:
            out[key + "_pages"] = PG.scatter_page(
                cache[key + "_pages"], page_ids, offsets, tok, n_lead=len(lead))
    for k, v in view.items():
        if k not in spec:
            out[k] = v
    return out


def _page_size_of(cfg, cache):
    spec = get_model(cfg).paged_cache_spec(cfg)
    key, lead = next(iter(spec.items()))
    return cache[key + "_pages"].shape[len(lead) + 2]
