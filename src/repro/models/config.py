"""Unified model configuration covering all assigned architecture families."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str = "model"
    family: str = "dense"            # dense | moe | ssm | hybrid | encdec

    # trunk dims
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: Optional[int] = None   # default: d_model // n_heads

    # norms / misc
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-5
    use_bias: bool = False
    activation: str = "swiglu"       # swiglu | geglu | gelu
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma-style sqrt(d) embedding scale
    parallel_block: bool = False     # cohere-style parallel attn+mlp residual
    qk_norm: bool = False
    logit_softcap: Optional[float] = None

    # rope
    rope_theta: float = 10000.0
    partial_rotary_factor: float = 1.0

    # attention pattern
    local_window: Optional[int] = None   # sliding-window size for local layers
    local_global_period: Optional[int] = None  # gemma3: every Nth layer global
    cross_attn_group: Optional[int] = None     # vlm: group size; last-1 slot is cross
    n_cross_tokens: int = 1024                 # stub frontend token count

    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    first_k_dense: int = 0
    n_shared_experts: int = 0
    d_ff_dense: Optional[int] = None     # d_ff of dense-replace layers
    moe_groups: int = 1                  # GShard token groups (= data shards)

    # ssm (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    shared_attn_period: int = 6          # zamba2: shared block every N ssm layers

    # encdec
    n_enc_layers: int = 0
    n_dec_layers: int = 0

    # execution
    param_dtype: str = "float32"     # master weights
    compute_dtype: str = "bfloat16"  # activations / matmul inputs at scale
    attn_impl: str = "xla"           # xla (introspectable) | kernel (pallas)
    ssd_impl: str = "xla"
    remat: str = "none"              # none | full | dots
    act_shard: str = "none"          # none | tp | tp_sp (Megatron constraints)
    scan_layers_decode: bool = True  # False: unroll decode layers so XLA can
                                     # alias per-layer KV buffers (no scan-ys
                                     # double buffer — see EXPERIMENTS §Perf)
    vocab_pad_multiple: int = 128    # pad embedding tables (TPU lanes x TP)

    # assigned input shapes (seq_len, global_batch, kind) for the dry-run
    shapes: Tuple[Tuple[str, int, int, str], ...] = ()
    # families for which long_500k is skipped (full attention) — see DESIGN.md
    skip_long_context: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline bookkeeping)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.resolved_head_dim
        qo = self.n_heads * hd
        kvd = self.n_kv_heads * hd
        attn = d * qo + 2 * d * kvd + qo * d
        mlp_mult = 3 if self.activation in ("swiglu", "geglu") else 2
        mlp = mlp_mult * d * f
        emb = v * d * (1 if self.tie_embeddings else 2)

        if self.family == "dense":
            n = self.n_layers * (attn + mlp + 2 * d) + emb
            if self.cross_attn_group:
                n_cross = self.n_layers // self.cross_attn_group
                n += n_cross * (attn + mlp + 2 * d)
            return n
        if self.family == "moe":
            moe_mlp = mlp_mult * d * f * self.n_experts + d * self.n_experts
            shared = mlp_mult * d * f * self.n_shared_experts
            dense_layers = self.first_k_dense
            fd = self.d_ff_dense or f
            n = (self.n_layers - dense_layers) * (attn + moe_mlp + shared + 2 * d)
            n += dense_layers * (attn + mlp_mult * d * fd + 2 * d)
            return n + emb
        if self.family == "ssm":
            di, ns = self.d_inner, self.ssm_state
            blk = (d * (2 * di + 2 * ns + self.n_ssm_heads)   # in_proj
                   + (di + 2 * ns) * self.ssm_conv_width       # conv
                   + di * d + 3 * self.n_ssm_heads + d)        # out_proj, A/D/dt_b, norm
            return self.n_layers * blk + emb
        if self.family == "hybrid":
            di, ns = self.d_inner, self.ssm_state
            blk = (d * (2 * di + 2 * ns + self.n_ssm_heads)
                   + (di + 2 * ns) * self.ssm_conv_width + di * d
                   + 3 * self.n_ssm_heads + d)
            shared_blk = attn + mlp + 2 * d
            return self.n_layers * blk + shared_blk + emb
        if self.family == "encdec":
            enc = self.n_enc_layers * (attn + mlp + 2 * d)
            dec = self.n_dec_layers * (2 * attn + mlp + 3 * d)
            return enc + dec + emb
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp_mult = 3 if self.activation in ("swiglu", "geglu") else 2
        total = self.param_count()
        all_experts = (self.n_layers - self.first_k_dense) * mlp_mult * d * f * self.n_experts
        active = (self.n_layers - self.first_k_dense) * mlp_mult * d * f * self.top_k
        return total - all_experts + active
