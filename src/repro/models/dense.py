"""Dense decoder-LM family: stablelm-3b/12b, command-r-plus, gemma3, and the
llama-3.2-vision backbone (grouped cross-attention layers).

Implementation notes
--------------------
* scan-over-layers with stacked params: HLO size is O(1) in depth.
* gemma3's 5:1 local:global pattern is ONE predicated attention with a
  *dynamic* per-layer window scalar (2**30 = global) — no duplicated branches
  (the SVE predication story: the mask changes, never the code).
* llama-vision: layers grouped in blocks of ``cross_attn_group`` (5); slot 3
  of each group is a cross-attention layer reading stub image embeddings
  (the modality frontend is a ShapeDtypeStruct stand-in per the task spec).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from . import layers as L

NO_WINDOW = 2 ** 30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def axes(cfg):
    """Logical-axis tree mirroring init's params (cheap, array-free)."""
    ax = {"embed": L.embed_axes(cfg), "final_norm": L.norm_axes(cfg)}
    if cfg.cross_attn_group:
        ax["groups"] = {
            "self": L.stack_axes(L.stack_axes(L.block_axes(cfg))),
            "cross": L.stack_axes(L.block_axes(cfg)),
        }
    else:
        ax["blocks"] = L.stack_axes(L.block_axes(cfg))
    return ax


def init(key, cfg):
    k_emb, k_blocks, k_cross = jax.random.split(key, 3)
    params = {"embed": L.embed_init(k_emb, cfg),
              "final_norm": L.norm_init(cfg, cfg.d_model)}
    if cfg.cross_attn_group:
        g = cfg.cross_attn_group
        n_groups, n_self = cfg.n_layers // g, g - 1
        params["groups"] = {
            "self": L.stack_init(
                k_blocks, n_groups,
                lambda k: L.stack_init(k, n_self, lambda k2: L.block_init(k2, cfg))),
            "cross": L.stack_init(k_cross, n_groups,
                                  lambda k: L.block_init(k, cfg)),
        }
    else:
        params["blocks"] = L.stack_init(k_blocks, cfg.n_layers,
                                        lambda k: L.block_init(k, cfg))
    return params, axes(cfg)


def layer_windows(cfg):
    """(L,) int32 per-layer dynamic window (NO_WINDOW = global attention)."""
    idx = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    if cfg.local_window is None:
        return jnp.full((cfg.n_layers,), NO_WINDOW, jnp.int32)
    if cfg.local_global_period is None:
        return jnp.full((cfg.n_layers,), cfg.local_window, jnp.int32)
    is_global = (idx % cfg.local_global_period) == (cfg.local_global_period - 1)
    return jnp.where(is_global, NO_WINDOW, cfg.local_window).astype(jnp.int32)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _trunk_plain(params, cfg, x, positions, kv_lens):
    wins = layer_windows(cfg)

    def body(h, xs):
        lp, win = xs
        h, _ = L.block_apply(lp, h, positions, cfg, causal=True, window=win,
                             kv_lens=kv_lens)
        return h, None

    h, _ = jax.lax.scan(L.remat_wrap(body, cfg), x, (params["blocks"], wins))
    return h


def _trunk_vlm(params, cfg, x, positions, kv_lens, cross_emb):
    """Groups of (pre self layers, cross layer, 1 self layer): HF llama-3.2
    cross_attention_layers = [3, 8, 13, ...] with group size 5 and pre = 3."""
    g = cfg.cross_attn_group
    pre = g - 2

    def self_body(h, lp):
        h, _ = L.block_apply(lp, h, positions, cfg, causal=True, kv_lens=kv_lens)
        return h, None

    def group_body(h, gp):
        h, _ = jax.lax.scan(self_body, h,
                            jax.tree.map(lambda a: a[:pre], gp["self"]))
        h, _ = L.block_apply(gp["cross"], h, positions, cfg, kv_x=cross_emb,
                             causal=False, use_rope=False)
        h, _ = self_body(h, jax.tree.map(lambda a: a[pre], gp["self"]))
        return h, None

    h, _ = jax.lax.scan(L.remat_wrap(group_body, cfg), x, params["groups"])
    return h


def train_logits(params, cfg, batch):
    """batch: tokens (B, S) [+ lens (B,)] [+ cross_emb (B, N, d)]."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    kv_lens = batch.get("lens")
    x = L.embed(params["embed"], tokens, cfg)
    if cfg.cross_attn_group:
        h = _trunk_vlm(params, cfg, x, positions, kv_lens, batch["cross_emb"])
    else:
        h = _trunk_plain(params, cfg, x, positions, kv_lens)
    h = L.apply_norm(params["final_norm"], h, cfg)
    return L.unembed(params["embed"], h, cfg), {}


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with KV caches
# ---------------------------------------------------------------------------

def make_cache(cfg, batch_size: int, max_len: int, dtype=None):
    """Allocate the decode cache pytree (zeros)."""
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    shp = (batch_size, hkv, max_len, hd)
    if cfg.cross_attn_group:
        g = cfg.cross_attn_group
        n_groups, n_self = cfg.n_layers // g, g - 1
        return {
            "k": jnp.zeros((n_groups, n_self) + shp, dtype),
            "v": jnp.zeros((n_groups, n_self) + shp, dtype),
            "cross_k": jnp.zeros((n_groups, batch_size, hkv, cfg.n_cross_tokens, hd), dtype),
            "cross_v": jnp.zeros((n_groups, batch_size, hkv, cfg.n_cross_tokens, hd), dtype),
            "pos": jnp.zeros((batch_size,), jnp.int32),
        }
    return {
        "k": jnp.zeros((cfg.n_layers,) + shp, dtype),
        "v": jnp.zeros((cfg.n_layers,) + shp, dtype),
        "pos": jnp.zeros((batch_size,), jnp.int32),
    }


def cache_batch_axes(cfg):
    """Which axis of each cache array is the request-lane (batch) axis.

    The serve scheduler treats a batch as a vector of request lanes (SVE
    §2.3.4); ``repro.models.gather_lanes``/``slot_update`` consume this map to
    permute or refill lanes as pure index gathers/scatters — no shape guessing.
    """
    if cfg.cross_attn_group:
        return {"k": 2, "v": 2, "cross_k": 1, "cross_v": 1, "pos": 0}
    return {"k": 1, "v": 1, "pos": 0}


# full prefix state lives in paged KV + pos, so prefix sharing is sound
PAGED_PREFIX_OK = True

# prefill() takes per-row pos0 start offsets with all state in the KV cache,
# so one prompt's prefill can be split into chunks (scheduler chunked prefill)
CHUNKED_PREFILL_OK = True
# decode has no cross-lane coupling: bursts may narrow to a lane prefix
LANE_INDEPENDENT_DECODE = True


def paged_decode_ok(cfg):
    """decode() accepts a paged cache directly (flash attention reads K/V
    through the page table instead of a gathered dense view).  Holds for the
    vlm variant too: self-attention K/V pages, cross K/V stays per-lane."""
    return True


def paged_cache_spec(cfg):
    """KV cache keys with a (max_len) token axis -> their leading layer dims.

    Cross-attention K/V (llama-vision) are per-request constants, not
    token-indexed, so they stay per-lane dense arrays.
    """
    if cfg.cross_attn_group:
        g = cfg.cross_attn_group
        return {"k": (cfg.n_layers // g, g - 1), "v": (cfg.n_layers // g, g - 1)}
    return {"k": (cfg.n_layers,), "v": (cfg.n_layers,)}


def make_paged_cache(cfg, batch_size: int, max_len: int, *, page_size: int,
                     pool_pages: int, dtype=None, page_dtype=None):
    """Paged decode cache: shared page pools + per-lane page table (+ the
    non-token-indexed remainder of make_cache).  ``page_dtype`` ("int8" /
    "fp8") stores pools narrow with per-slot scale pools riding alongside."""
    from repro.core import paging as PG
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    cache = PG.alloc_pools(paged_cache_spec(cfg), pool_pages, page_size,
                           hkv, hd, dtype, page_dtype=page_dtype)
    cache["page_table"] = jnp.zeros(
        (batch_size, PG.pages_needed(max_len, page_size)), jnp.int32)
    cache["pos"] = jnp.zeros((batch_size,), jnp.int32)
    if cfg.cross_attn_group:
        g = cfg.cross_attn_group
        n_groups = cfg.n_layers // g
        cache["cross_k"] = jnp.zeros(
            (n_groups, batch_size, hkv, cfg.n_cross_tokens, hd), dtype)
        cache["cross_v"] = jnp.zeros(
            (n_groups, batch_size, hkv, cfg.n_cross_tokens, hd), dtype)
    return cache


def _cross_kv(params_cross_attn, cross_emb, cfg):
    """Precompute cross K/V from (stub) image embeddings for one group."""
    hd = cfg.resolved_head_dim
    src = cross_emb.astype(L.cdt(cfg))
    k = L._split_heads(src @ params_cross_attn["wk"].astype(L.cdt(cfg)),
                       cfg.n_kv_heads, hd)
    v = L._split_heads(src @ params_cross_attn["wv"].astype(L.cdt(cfg)),
                       cfg.n_kv_heads, hd)
    return k, v


def prefill(params, cfg, batch, cache):
    """Run the prompt, fill caches, return (last-token logits, cache).

    batch: tokens (B, S), lens (B,) [+ cross_emb] [+ pos0 (B,)].  The cache
    must have max_len >= pos0 + S.  Per-row ragged lengths are first-class
    (whilelt masks).  ``pos0`` is the per-row start offset of a SUFFIX
    prefill: rows whose prompt prefix is already resident in the cache
    (prefix sharing) run only their suffix tokens, attending over the cached
    prefix K/V at positions [0, pos0) — per-row numerics are identical to a
    cold prefill of the full prompt because K/V blocking depends only on the
    cache length and each query row's mask depends only on its absolute
    position.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    lens = batch.get("lens")
    lens = jnp.full((b,), s, jnp.int32) if lens is None else jnp.asarray(lens, jnp.int32)
    pos0 = batch.get("pos0")
    pos0 = jnp.zeros((b,), jnp.int32) if pos0 is None else jnp.asarray(pos0, jnp.int32)
    positions = pos0[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
    kv_lens = pos0 + lens
    x = L.embed(params["embed"], tokens, cfg)
    wins = layer_windows(cfg)

    if cfg.cross_attn_group:
        g = cfg.cross_attn_group
        pre = g - 2
        cross_emb = batch["cross_emb"]
        n_groups = cfg.n_layers // g
        h = x
        new_k, new_v, cks, cvs = [], [], [], []
        for gi in range(n_groups):                  # 8 groups: unrolled
            gp = jax.tree.map(lambda a, gi=gi: a[gi], params["groups"])
            ks_g, vs_g = [], []
            for si in range(g - 1):
                if si == pre:                       # cross before self slot `pre`
                    ck, cv = _cross_kv(gp["cross"]["attn"], cross_emb, cfg)
                    h, _ = L.block_apply(gp["cross"], h, positions, cfg,
                                         kv_x=cross_emb, causal=False,
                                         use_rope=False)
                    cks.append(ck)
                    cvs.append(cv)
                lp = jax.tree.map(lambda a, si=si: a[si], gp["self"])
                h, (kn, vn) = L.block_apply(
                    lp, h, positions, cfg, causal=True, kv_lens=kv_lens,
                    q_offset=pos0, cache=(cache["k"][gi, si], cache["v"][gi, si]),
                    cache_pos=pos0)
                ks_g.append(kn)
                vs_g.append(vn)
            new_k.append(jnp.stack(ks_g))
            new_v.append(jnp.stack(vs_g))
        cache = dict(cache)
        cache["k"], cache["v"] = jnp.stack(new_k), jnp.stack(new_v)
        cache["cross_k"], cache["cross_v"] = jnp.stack(cks), jnp.stack(cvs)
    else:
        def body(carry, xs):
            h, = carry
            lp, win, kc, vc = xs
            h, (kc, vc) = L.block_apply(
                lp, h, positions, cfg, causal=True, window=win, kv_lens=kv_lens,
                q_offset=pos0, cache=(kc, vc), cache_pos=pos0)
            return (h,), (kc, vc)

        (h,), (k_new, v_new) = jax.lax.scan(
            body, (x,), (params["blocks"], wins, cache["k"], cache["v"]))
        cache = dict(cache)
        cache["k"], cache["v"] = k_new, v_new

    cache["pos"] = pos0 + lens
    h = L.apply_norm(params["final_norm"], h, cfg)
    # logits at each row's last valid position (ragged gather)
    idx = jnp.clip(lens - 1, 0, s - 1)
    h_last = jnp.take_along_axis(h, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    logits = L.unembed(params["embed"], h_last[:, None], cfg)[:, 0]
    return logits, cache


def decode(params, cfg, batch, cache):
    """One-token decode: batch = {"token": (B, 1)}.  Returns (logits, cache)."""
    token = batch["token"]
    b = token.shape[0]
    pos = cache["pos"]                              # (B,) current lengths
    positions = pos[:, None]
    x = L.embed(params["embed"], token, cfg)
    wins = layer_windows(cfg)

    if cfg.cross_attn_group:
        g = cfg.cross_attn_group
        pre = g - 2
        n_groups = cfg.n_layers // g
        h = x
        paged = "k_pages" in cache
        ksc = vsc = None
        if paged:
            # native paged vlm decode: self-attention K/V lives in page pools
            # (lead (n_groups, n_self)); cross K/V stays a per-lane constant
            kc, vc = cache["k_pages"], cache["v_pages"]
            ksc = cache.get("k_pages_scale")
            vsc = cache.get("v_pages_scale")
            table = cache["page_table"]
        else:
            kc, vc = cache["k"], cache["v"]
        for gi in range(n_groups):
            gp = jax.tree.map(lambda a, gi=gi: a[gi], params["groups"])
            for si in range(g - 1):
                if si == pre:                       # cross before self slot `pre`
                    h = _cross_decode(gp["cross"], h, positions, cfg,
                                      cache["cross_k"][gi], cache["cross_v"][gi])
                lp = jax.tree.map(lambda a, si=si: a[si], gp["self"])
                if not paged:
                    layer_cache = (kc[gi, si], vc[gi, si])
                elif ksc is None:
                    layer_cache = (kc[gi, si], vc[gi, si], table)
                else:
                    layer_cache = (kc[gi, si], vc[gi, si], table,
                                   ksc[gi, si], vsc[gi, si])
                h, new_kv = L.block_apply(
                    lp, h, positions, cfg, causal=False, kv_lens=pos + 1,
                    q_offset=pos, cache=layer_cache, cache_pos=pos)
                kc = kc.at[gi, si].set(new_kv[0])
                vc = vc.at[gi, si].set(new_kv[1])
                if ksc is not None:
                    ksc = ksc.at[gi, si].set(new_kv[2])
                    vsc = vsc.at[gi, si].set(new_kv[3])
        cache = dict(cache)
        if paged:
            cache["k_pages"], cache["v_pages"] = kc, vc
            if ksc is not None:
                cache["k_pages_scale"], cache["v_pages_scale"] = ksc, vsc
        else:
            cache["k"], cache["v"] = kc, vc
    elif "k_pages" in cache:
        # native paged decode: each layer's attention scatter-stores the new
        # token into its page and gathers K/V blocks through the page table
        # (SVE §2.3.3) — the pool, not a per-lane dense cache, is the operand
        h = x
        kp, vp = cache["k_pages"], cache["v_pages"]     # (L, P, Hkv, ps, Dh)
        ksc = cache.get("k_pages_scale")                # (L, P, Hkv, ps) | None
        vsc = cache.get("v_pages_scale")
        table = cache["page_table"]
        dus = jax.lax.dynamic_update_slice_in_dim
        for li in range(cfg.n_layers):
            lp = jax.tree.map(lambda a, li=li: a[li], params["blocks"])
            layer_cache = ((kp[li], vp[li], table) if ksc is None
                           else (kp[li], vp[li], table, ksc[li], vsc[li]))
            h, new_kv = L.block_apply(
                lp, h, positions, cfg, causal=False, window=wins[li],
                kv_lens=pos + 1, q_offset=pos, cache=layer_cache,
                cache_pos=pos)
            kp = dus(kp, new_kv[0][None], li, axis=0)
            vp = dus(vp, new_kv[1][None], li, axis=0)
            if ksc is not None:
                ksc = dus(ksc, new_kv[2][None], li, axis=0)
                vsc = dus(vsc, new_kv[3][None], li, axis=0)
        cache = dict(cache)
        cache["k_pages"], cache["v_pages"] = kp, vp
        if ksc is not None:
            cache["k_pages_scale"], cache["v_pages_scale"] = ksc, vsc
    elif not cfg.scan_layers_decode:
        # unrolled decode: per-layer dynamic-update-slice on the STACKED cache
        # lets XLA alias in place — no scan-ys double buffer (EXPERIMENTS §Perf)
        h = x
        kc, vc = cache["k"], cache["v"]
        for li in range(cfg.n_layers):
            lp = jax.tree.map(lambda a, li=li: a[li], params["blocks"])
            h, (kl, vl) = L.block_apply(
                lp, h, positions, cfg, causal=False, window=wins[li],
                kv_lens=pos + 1, q_offset=pos, cache=(kc[li], vc[li]),
                cache_pos=pos)
            kc = jax.lax.dynamic_update_slice_in_dim(kc, kl[None], li, axis=0)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, vl[None], li, axis=0)
        cache = dict(cache)
        cache["k"], cache["v"] = kc, vc
    else:
        def body(carry, xs):
            h, = carry
            lp, win, kc, vc = xs
            h, (kc, vc) = L.block_apply(
                lp, h, positions, cfg, causal=False, window=win,
                kv_lens=pos + 1, q_offset=pos, cache=(kc, vc), cache_pos=pos)
            return (h,), (kc, vc)

        (h,), (k_new, v_new) = jax.lax.scan(
            body, (x,), (params["blocks"], wins, cache["k"], cache["v"]))
        cache = dict(cache)
        cache["k"], cache["v"] = k_new, v_new

    cache["pos"] = pos + 1
    h = L.apply_norm(params["final_norm"], h, cfg)
    logits = L.unembed(params["embed"], h, cfg)[:, 0]
    return logits, cache


def _cross_decode(block_p, h, positions, cfg, ck, cv):
    """Cross-attention sub-block against precomputed cross K/V."""
    from repro.kernels.flash_attention import flash_attention
    hd = cfg.resolved_head_dim
    hin = L.apply_norm(block_p["ln1"], h, cfg)
    q = L._split_heads(hin.astype(L.cdt(cfg)) @ block_p["attn"]["wq"].astype(L.cdt(cfg)),
                       cfg.n_heads, hd)
    if cfg.qk_norm:
        q = L._rms_headdim(q)
    out = flash_attention(q, ck.astype(L.cdt(cfg)), cv.astype(L.cdt(cfg)),
                          causal=False, impl=cfg.attn_impl)
    out = L._merge_heads(out).astype(L.cdt(cfg)) @ block_p["attn"]["wo"].astype(L.cdt(cfg))
    if cfg.parallel_block:
        h = h + out + L.mlp(block_p["mlp"], hin, cfg)
    else:
        h2 = h + out
        h = h2 + L.mlp(block_p["mlp"], L.apply_norm(block_p["ln2"], h2, cfg), cfg)
    return h
