"""Encoder-decoder backbone (seamless-m4t-large-v2).

The speech/modality frontend is a STUB per the task spec: ``input_specs``
provides precomputed frame embeddings (B, S_src, d_model) directly to the
encoder.  Encoder: non-causal self-attention over ragged frame lengths
(whilelt predicates).  Decoder: causal self-attention + cross-attention to
the encoder memory; serving caches self K/V incrementally and cross K/V once.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention

from . import layers as L


def _dec_block_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L.norm_init(cfg, cfg.d_model), "self_attn": L.attn_init(k1, cfg),
            "lnx": L.norm_init(cfg, cfg.d_model), "cross_attn": L.attn_init(k2, cfg),
            "ln2": L.norm_init(cfg, cfg.d_model), "mlp": L.mlp_init(k3, cfg)}


def _dec_block_axes(cfg):
    return {"ln1": L.norm_axes(cfg), "self_attn": L.attn_axes(cfg),
            "lnx": L.norm_axes(cfg), "cross_attn": L.attn_axes(cfg),
            "ln2": L.norm_axes(cfg), "mlp": L.mlp_axes(cfg)}


def _cross_kv(p, memory, cfg):
    """Precompute cross-attention K/V from encoder memory, mirroring
    ``L.attention``'s kv_x path op-for-op (bias, head split, qk_norm) so that
    attending through the cache is bitwise identical to attending through
    ``kv_x=memory`` — required for chunked prefill to resume exactly."""
    cd = L.cdt(cfg)
    hd = cfg.resolved_head_dim
    ck = memory.astype(cd) @ p["wk"].astype(cd)
    cv = memory.astype(cd) @ p["wv"].astype(cd)
    if cfg.use_bias:
        ck = ck + p["bk"].astype(cd)
        cv = cv + p["bv"].astype(cd)
    ck = L.shard_act(cfg, L._split_heads(ck, cfg.n_kv_heads, hd),
                     ("batch", "act_kv_heads", None, None))
    cv = L.shard_act(cfg, L._split_heads(cv, cfg.n_kv_heads, hd),
                     ("batch", "act_kv_heads", None, None))
    if cfg.qk_norm:
        ck = L._rms_headdim(ck)
    return ck, cv


def _cross_from_cache(p, hx, cfg, ck, cv, src_lens):
    """Cross-attention against cached K/V, mirroring ``L.attention``'s kv_x
    path on the query/output side (bias, qk_norm, shard annotations)."""
    cd = L.cdt(cfg)
    hd = cfg.resolved_head_dim
    q = hx.astype(cd) @ p["wq"].astype(cd)
    if cfg.use_bias:
        q = q + p["bq"].astype(cd)
    q = L.shard_act(cfg, L._split_heads(q, cfg.n_heads, hd),
                    ("batch", "act_heads", None, None))
    if cfg.qk_norm:
        q = L._rms_headdim(q)
    out = flash_attention(q, ck.astype(cd), cv.astype(cd),
                          kv_lens=src_lens, causal=False, impl=cfg.attn_impl)
    out = L.shard_act(cfg, out, ("batch", "act_heads", None, None))
    out = L._merge_heads(out).astype(cd) @ p["wo"].astype(cd)
    if cfg.use_bias:
        out = out + p["bo"].astype(cd)
    out = L.shard_act(cfg, out, ("batch", None, None))
    return out.astype(hx.dtype)


def _dec_block_apply(p, x, positions, cfg, memory, *, src_lens=None,
                     kv_lens=None, q_offset=None, cache=None, cache_pos=None,
                     cross_cache=None, causal=True):
    x = L.shard_residual(cfg, x)
    h = L.apply_norm(p["ln1"], x, cfg)
    attn_out, new_cache = L.attention(
        p["self_attn"], h, positions, cfg, causal=causal, kv_lens=kv_lens,
        q_offset=q_offset, cache=cache, cache_pos=cache_pos)
    h2 = x + attn_out
    hx = L.apply_norm(p["lnx"], h2, cfg)
    if cross_cache is not None:        # decode / resumed chunk: cached cross K/V
        ck, cv = cross_cache
        cross_out = _cross_from_cache(p["cross_attn"], hx, cfg, ck, cv,
                                      src_lens)
    else:
        cross_out, _ = L.attention(
            p["cross_attn"], hx, positions, cfg, kv_x=memory, causal=False,
            kv_lens=src_lens, use_rope=False)
    h3 = h2 + cross_out
    out = h3 + L.mlp(p["mlp"], L.apply_norm(p["ln2"], h3, cfg), cfg)
    return L.shard_residual(cfg, out), new_cache


def axes(cfg):
    return {
        "embed": L.embed_axes(cfg),
        "enc_blocks": L.stack_axes(L.block_axes(cfg)),
        "enc_norm": L.norm_axes(cfg),
        "dec_blocks": L.stack_axes(_dec_block_axes(cfg)),
        "final_norm": L.norm_axes(cfg),
    }


def init(key, cfg):
    k_emb, k_enc, k_dec = jax.random.split(key, 3)
    params = {
        "embed": L.embed_init(k_emb, cfg),
        "enc_blocks": L.stack_init(k_enc, cfg.n_enc_layers,
                                   lambda k: L.block_init(k, cfg)),
        "enc_norm": L.norm_init(cfg, cfg.d_model),
        "dec_blocks": L.stack_init(k_dec, cfg.n_dec_layers,
                                   lambda k: _dec_block_init(k, cfg)),
        "final_norm": L.norm_init(cfg, cfg.d_model),
    }
    return params, axes(cfg)


def encode(params, cfg, src_emb, src_lens=None):
    b, s_src, _ = src_emb.shape
    positions = jnp.broadcast_to(jnp.arange(s_src, dtype=jnp.int32)[None],
                                 (b, s_src))

    def body(h, lp):
        h, _ = L.block_apply(lp, h, positions, cfg, causal=False,
                             kv_lens=src_lens)
        return h, None

    h, _ = jax.lax.scan(L.remat_wrap(body, cfg), src_emb.astype(L.cdt(cfg)),
                        params["enc_blocks"])
    return L.apply_norm(params["enc_norm"], h, cfg)


def train_logits(params, cfg, batch):
    """batch: src_emb (B, Ss, d) [+ src_lens], tokens (B, St) [+ lens]."""
    memory = encode(params, cfg, batch["src_emb"], batch.get("src_lens"))
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = L.embed(params["embed"], tokens, cfg)

    def body(h, lp):
        h, _ = _dec_block_apply(lp, h, positions, cfg, memory,
                                src_lens=batch.get("src_lens"),
                                kv_lens=batch.get("lens"), causal=True)
        return h, None

    h, _ = jax.lax.scan(L.remat_wrap(body, cfg), x, params["dec_blocks"])
    h = L.apply_norm(params["final_norm"], h, cfg)
    return L.unembed(params["embed"], h, cfg), {}


def make_cache(cfg, batch_size: int, max_len: int, src_len: int, dtype=None):
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    lcount = cfg.n_dec_layers
    return {
        "k": jnp.zeros((lcount, batch_size, hkv, max_len, hd), dtype),
        "v": jnp.zeros((lcount, batch_size, hkv, max_len, hd), dtype),
        "cross_k": jnp.zeros((lcount, batch_size, hkv, src_len, hd), dtype),
        "cross_v": jnp.zeros((lcount, batch_size, hkv, src_len, hd), dtype),
        "src_lens": jnp.zeros((batch_size,), jnp.int32),
        "pos": jnp.zeros((batch_size,), jnp.int32),
    }


def cache_batch_axes(cfg):
    """Request-lane axis of each cache array (see repro.models.gather_lanes)."""
    return {"k": 1, "v": 1, "cross_k": 1, "cross_v": 1,
            "src_lens": 0, "pos": 0}


# cross K/V depend on the (per-request) source memory, so a shared text
# prefix does not imply shared decoder state
PAGED_PREFIX_OK = False

# the first chunk runs the encoder and caches per-layer cross K/V; resumed
# chunks (no src_emb in the batch) attend the cached K/V — bitwise identical
# to the kv_x path because the cache stores post-bias/qk_norm heads at the
# compute dtype (lossless roundtrip)
CHUNKED_PREFILL_OK = True
# decode has no cross-lane coupling: bursts may narrow to a lane prefix
LANE_INDEPENDENT_DECODE = True


def paged_decode_ok(cfg):
    """decode() reads decoder self-attention K/V through the page table;
    cross K/V is a per-request constant and stays per-lane dense."""
    return True


def paged_cache_spec(cfg):
    """Only decoder self-attention K/V grows with the target length; cross
    K/V is a per-request constant of the source frames."""
    return {"k": (cfg.n_dec_layers,), "v": (cfg.n_dec_layers,)}


def make_paged_cache(cfg, batch_size: int, max_len: int, src_len: int = 1, *,
                     page_size: int, pool_pages: int, dtype=None,
                     page_dtype=None):
    from repro.core import paging as PG
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    lcount = cfg.n_dec_layers
    cache = PG.alloc_pools(paged_cache_spec(cfg), pool_pages, page_size,
                           hkv, hd, dtype, page_dtype=page_dtype)
    cache["page_table"] = jnp.zeros(
        (batch_size, PG.pages_needed(max_len, page_size)), jnp.int32)
    cache["cross_k"] = jnp.zeros((lcount, batch_size, hkv, src_len, hd), dtype)
    cache["cross_v"] = jnp.zeros((lcount, batch_size, hkv, src_len, hd), dtype)
    cache["src_lens"] = jnp.zeros((batch_size,), jnp.int32)
    cache["pos"] = jnp.zeros((batch_size,), jnp.int32)
    return cache


def prefill(params, cfg, batch, cache):
    """Encode source + run decoder prompt, filling self and cross caches.

    Chunked-prefill resume: when ``batch`` has no ``src_emb``, the encoder is
    NOT re-run — cross-attention reads the cached per-layer cross K/V written
    by the first chunk (bitwise identical to attending the memory directly,
    see ``_cross_kv``), and ``pos0`` offsets the self-attention writes."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    lens = batch.get("lens")
    lens = jnp.full((b,), s, jnp.int32) if lens is None else jnp.asarray(lens, jnp.int32)
    pos0 = batch.get("pos0")
    pos0 = jnp.zeros((b,), jnp.int32) if pos0 is None else jnp.asarray(pos0, jnp.int32)
    positions = pos0[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
    x = L.embed(params["embed"], tokens, cfg)
    cache = dict(cache)

    if "src_emb" in batch:                     # first chunk: run the encoder
        src_lens = batch.get("src_lens")
        memory = encode(params, cfg, batch["src_emb"], src_lens)
        if src_lens is None:
            src_lens = jnp.full((b,), memory.shape[1], jnp.int32)

        def body(carry, xs):
            h, = carry
            lp, kc, vc = xs
            h, (kc, vc) = _dec_block_apply(
                lp, h, positions, cfg, memory, src_lens=src_lens,
                kv_lens=pos0 + lens, q_offset=pos0, cache=(kc, vc),
                cache_pos=pos0, causal=True)
            # cross K/V for decode + resumed chunks (computed once per layer)
            ck, cv = _cross_kv(lp["cross_attn"], memory, cfg)
            return (h,), (kc, vc, ck, cv)

        (h,), (k_new, v_new, ck, cv) = jax.lax.scan(
            body, (x,), (params["dec_blocks"], cache["k"], cache["v"]))
        cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
        cache["cross_v"] = cv.astype(cache["cross_v"].dtype)
        cache["src_lens"] = src_lens
    else:                                      # resumed chunk: cached cross K/V
        src_lens = cache["src_lens"]

        def body(carry, xs):
            h, = carry
            lp, kc, vc, ck, cv = xs
            h, (kc, vc) = _dec_block_apply(
                lp, h, positions, cfg, None, src_lens=src_lens,
                kv_lens=pos0 + lens, q_offset=pos0, cache=(kc, vc),
                cache_pos=pos0, cross_cache=(ck, cv), causal=True)
            return (h,), (kc, vc)

        (h,), (k_new, v_new) = jax.lax.scan(
            body, (x,), (params["dec_blocks"], cache["k"], cache["v"],
                         cache["cross_k"], cache["cross_v"]))

    cache["k"], cache["v"] = k_new, v_new
    cache["pos"] = pos0 + lens
    h = L.apply_norm(params["final_norm"], h, cfg)
    idx = jnp.clip(lens - 1, 0, s - 1)
    h_last = jnp.take_along_axis(h, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    return L.unembed(params["embed"], h_last[:, None], cfg)[:, 0], cache


def _decode_paged(params, cfg, x, positions, cache):
    """Native paged decode: each decoder layer's self-attention gathers K/V
    pages through the table and scatter-stores the new token into the lane's
    tail page; cross-attention reads the per-lane dense cross cache.  Layers
    unrolled so the per-layer pool write aliases in place."""
    pos = cache["pos"]
    table = cache["page_table"]
    cache = dict(cache)
    kp, vp = cache["k_pages"], cache["v_pages"]
    ksc = cache.get("k_pages_scale")
    vsc = cache.get("v_pages_scale")
    h = x
    dus = jax.lax.dynamic_update_slice_in_dim
    for li in range(cfg.n_dec_layers):
        lp = jax.tree.map(lambda a, li=li: a[li], params["dec_blocks"])
        layer_cache = ((kp[li], vp[li], table) if ksc is None
                       else (kp[li], vp[li], table, ksc[li], vsc[li]))
        h, new_kv = _dec_block_apply(
            lp, h, positions, cfg, None, src_lens=cache["src_lens"],
            kv_lens=pos + 1, q_offset=pos, cache=layer_cache,
            cache_pos=pos,
            cross_cache=(cache["cross_k"][li], cache["cross_v"][li]),
            causal=False)
        kp = dus(kp, new_kv[0][None], li, axis=0)
        vp = dus(vp, new_kv[1][None], li, axis=0)
        if ksc is not None:
            ksc = dus(ksc, new_kv[2][None], li, axis=0)
            vsc = dus(vsc, new_kv[3][None], li, axis=0)
    cache["k_pages"], cache["v_pages"] = kp, vp
    if ksc is not None:
        cache["k_pages_scale"], cache["v_pages_scale"] = ksc, vsc
    return h, cache


def decode(params, cfg, batch, cache):
    token = batch["token"]
    pos = cache["pos"]
    positions = pos[:, None]
    x = L.embed(params["embed"], token, cfg)

    if "k_pages" in cache:
        h, cache = _decode_paged(params, cfg, x, positions, cache)
        cache["pos"] = pos + 1
        h = L.apply_norm(params["final_norm"], h, cfg)
        return L.unembed(params["embed"], h, cfg)[:, 0], cache

    def body(carry, xs):
        h, = carry
        lp, kc, vc, ck, cv = xs
        h, (kc, vc) = _dec_block_apply(
            lp, h, positions, cfg, None, src_lens=cache["src_lens"],
            kv_lens=pos + 1, q_offset=pos, cache=(kc, vc), cache_pos=pos,
            cross_cache=(ck, cv), causal=False)
        return (h,), (kc, vc)

    (h,), (k_new, v_new) = jax.lax.scan(
        body, (x,), (params["dec_blocks"], cache["k"], cache["v"],
                     cache["cross_k"], cache["cross_v"]))
    cache = dict(cache)
    cache["k"], cache["v"] = k_new, v_new
    cache["pos"] = pos + 1
    h = L.apply_norm(params["final_norm"], h, cfg)
    return L.unembed(params["embed"], h, cfg)[:, 0], cache
