"""Zamba2-style hybrid: a Mamba2 backbone with a SHARED transformer block
(single weight copy) applied every ``shared_attn_period`` SSM layers.

The shared block is the paper's C9 'one datapath, many widths' principle at
model scale: the same attention weights serve several depths, each
application keeping its own KV cache slot.  Simplifications vs the HF
implementation (per-application LoRA deltas, concatenated embedding input)
are recorded in DESIGN.md §4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from . import ssm as S


def _split_layout(cfg):
    period = cfg.shared_attn_period
    n_groups = cfg.n_layers // period
    rem = cfg.n_layers - n_groups * period
    return period, n_groups, rem


def axes(cfg):
    _, _, rem = _split_layout(cfg)
    ax = {"embed": L.embed_axes(cfg), "final_norm": L.norm_axes(cfg),
          "shared": L.block_axes(cfg),
          "main": L.stack_axes(L.stack_axes(S.mamba_block_axes(cfg)))}
    if rem:
        ax["tail"] = L.stack_axes(S.mamba_block_axes(cfg))
    return ax


def init(key, cfg):
    period, n_groups, rem = _split_layout(cfg)
    k_emb, k_main, k_rem, k_shared = jax.random.split(key, 4)
    params = {"embed": L.embed_init(k_emb, cfg),
              "final_norm": L.norm_init(cfg, cfg.d_model),
              "shared": L.block_init(k_shared, cfg)}
    main = L.stack_init(k_main, n_groups * period,
                        lambda k: S.mamba_block_init(k, cfg))
    params["main"] = jax.tree.map(
        lambda a: a.reshape((n_groups, period) + a.shape[1:]), main)
    if rem:
        params["tail"] = L.stack_init(k_rem, rem, lambda k: S.mamba_block_init(k, cfg))
    return params, axes(cfg)


def train_logits(params, cfg, batch):
    tokens = batch["tokens"]
    b, s = tokens.shape
    lens = batch.get("lens")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = L.embed(params["embed"], tokens, cfg)
    shared = params["shared"]

    def mamba_body(h, lp):
        h, _ = S.mamba_block(lp, h, cfg, seq_lens=lens)
        return h, None

    def group_body(h, gp):
        h, _ = jax.lax.scan(mamba_body, h, gp)
        h, _ = L.block_apply(shared, h, positions, cfg, causal=True, kv_lens=lens)
        return h, None

    h, _ = jax.lax.scan(L.remat_wrap(group_body, cfg), x, params["main"])
    if "tail" in params:
        h, _ = jax.lax.scan(mamba_body, h, params["tail"])
    h = L.apply_norm(params["final_norm"], h, cfg)
    return L.unembed(params["embed"], h, cfg), {}


def make_cache(cfg, batch_size: int, max_len: int, dtype=None):
    period, n_groups, rem = _split_layout(cfg)
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    ssm_cache = S.make_cache(cfg, batch_size, dtype=dtype)
    main_conv = ssm_cache["conv"][0]
    return {
        "conv": jnp.zeros((n_groups, period) + main_conv.shape, dtype),
        "state": jnp.zeros((n_groups, period, batch_size, cfg.n_ssm_heads,
                            cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
        "tail_conv": jnp.zeros((max(rem, 1),) + main_conv.shape, dtype),
        "tail_state": jnp.zeros((max(rem, 1), batch_size, cfg.n_ssm_heads,
                                 cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
        "shared_k": jnp.zeros((n_groups, batch_size, hkv, max_len, hd), dtype),
        "shared_v": jnp.zeros((n_groups, batch_size, hkv, max_len, hd), dtype),
        "pos": jnp.zeros((batch_size,), jnp.int32),
    }


def cache_batch_axes(cfg):
    """Request-lane axis of each cache array (see repro.models.gather_lanes)."""
    return {"conv": 2, "state": 2, "tail_conv": 1, "tail_state": 1,
            "shared_k": 1, "shared_v": 1, "pos": 0}


# conv/ssm state is NOT paged, so a prompt prefix is not fully captured by
# resident pages — prefix sharing would silently drop the SSM carry
PAGED_PREFIX_OK = False

# prefill() resumes the mamba stacks from the cached conv taps + SSM state
# (zero for a fresh cache — bitwise identical to no carry) and the shared
# attention block writes each chunk's K/V at its pos0 offset, so chunked
# prefill is exact at ssm_chunk-aligned boundaries.
CHUNKED_PREFILL_OK = True
# decode has no cross-lane coupling: bursts may narrow to a lane prefix
LANE_INDEPENDENT_DECODE = True


def chunked_prefill_granularity(cfg) -> int:
    """Chunk boundaries must align with the SSD scan chunk (see ssm.py)."""
    return int(cfg.ssm_chunk)


def paged_decode_ok(cfg):
    """decode() reads the shared attention block's K/V through the page
    table; conv/SSM state stays per-lane dense (it is O(1) in seq length)."""
    return True


def paged_cache_spec(cfg):
    """Only the shared attention block's K/V grows with sequence length; the
    mamba conv tails and SSM states stay per-lane O(1) arrays."""
    _, n_groups, _ = _split_layout(cfg)
    return {"shared_k": (n_groups,), "shared_v": (n_groups,)}


def make_paged_cache(cfg, batch_size: int, max_len: int, *, page_size: int,
                     pool_pages: int, dtype=None, page_dtype=None):
    from repro.core import paging as PG
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    dense = make_cache(cfg, batch_size, max_len, dtype=dtype)
    cache = {k: v for k, v in dense.items()
             if k not in ("shared_k", "shared_v")}
    cache.update(PG.alloc_pools(paged_cache_spec(cfg), pool_pages, page_size,
                                cfg.n_kv_heads, cfg.resolved_head_dim, dtype,
                                page_dtype=page_dtype))
    cache["page_table"] = jnp.zeros(
        (batch_size, PG.pages_needed(max_len, page_size)), jnp.int32)
    return cache


def _groups_cached(params, cfg, x, positions, cache, *, lens, q_offset,
                   cache_pos, causal, decode_step, kv_lens=None):
    shared = params["shared"]
    if kv_lens is None:
        kv_lens = lens if not decode_step else cache_pos + 1

    def group_body(carry, xs):
        h, = carry
        gp, conv_g, state_g, sk, sv = xs

        def mamba_body(carry2, xs2):
            h2, = carry2
            lp, cc, st = xs2
            if decode_step:
                h2, (cc, st) = S.mamba_block_decode(lp, h2, cfg, cc, st)
            else:
                h2, (cc, st) = S.mamba_block(lp, h2, cfg, seq_lens=lens,
                                             conv_init=cc, state_init=st)
            return (h2,), (cc, st)

        (h,), (conv_g, state_g) = jax.lax.scan(
            mamba_body, (h,), (gp, conv_g, state_g))
        h, (sk, sv) = L.block_apply(
            shared, h, positions, cfg, causal=causal, kv_lens=kv_lens,
            q_offset=q_offset, cache=(sk, sv), cache_pos=cache_pos)
        return (h,), (conv_g, state_g, sk, sv)

    (h,), (conv_new, state_new, sk_new, sv_new) = jax.lax.scan(
        group_body, (x,),
        (params["main"], cache["conv"], cache["state"],
         cache["shared_k"], cache["shared_v"]))

    cache = dict(cache)
    cache["conv"], cache["state"] = conv_new, state_new
    cache["shared_k"], cache["shared_v"] = sk_new, sv_new

    if "tail" in params:
        def tail_body(carry, xs):
            h2, = carry
            lp, cc, st = xs
            if decode_step:
                h2, (cc, st) = S.mamba_block_decode(lp, h2, cfg, cc, st)
            else:
                h2, (cc, st) = S.mamba_block(lp, h2, cfg, seq_lens=lens,
                                             conv_init=cc, state_init=st)
            return (h2,), (cc, st)
        (h,), (tc, ts) = jax.lax.scan(
            tail_body, (h,), (params["tail"], cache["tail_conv"],
                              cache["tail_state"]))
        cache["tail_conv"], cache["tail_state"] = tc, ts
    return h, cache


def prefill(params, cfg, batch, cache):
    tokens = batch["tokens"]
    b, s = tokens.shape
    lens = batch.get("lens")
    lens = jnp.full((b,), s, jnp.int32) if lens is None else jnp.asarray(lens, jnp.int32)
    pos0 = batch.get("pos0")                    # chunked-prefill resume offset
    pos0 = jnp.zeros((b,), jnp.int32) if pos0 is None else jnp.asarray(pos0, jnp.int32)
    positions = pos0[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
    x = L.embed(params["embed"], tokens, cfg)
    # conv caches are written by mamba_block's tail output; adapt shapes
    h, cache = _groups_cached(params, cfg, x, positions, cache, lens=lens,
                              q_offset=pos0, cache_pos=pos0, causal=True,
                              decode_step=False, kv_lens=pos0 + lens)
    cache["pos"] = pos0 + lens
    h = L.apply_norm(params["final_norm"], h, cfg)
    idx = jnp.clip(lens - 1, 0, s - 1)
    h_last = jnp.take_along_axis(h, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    return L.unembed(params["embed"], h_last[:, None], cfg)[:, 0], cache


def _decode_paged(params, cfg, x, positions, cache):
    """Native paged decode: the shared block's attention gathers K/V pages
    through the table and scatter-stores the new token into the lane's tail
    page; the mamba stacks run their usual per-lane O(1) state updates.
    Groups are unrolled so the per-group pool write aliases in place."""
    pos = cache["pos"]
    table = cache["page_table"]
    shared = params["shared"]
    cache = dict(cache)
    h = x
    conv, state = cache["conv"], cache["state"]
    skp, svp = cache["shared_k_pages"], cache["shared_v_pages"]
    sksc = cache.get("shared_k_pages_scale")
    svsc = cache.get("shared_v_pages_scale")
    n_groups = skp.shape[0]

    def mamba_body(carry, xs):
        h2, = carry
        lp, cc, st = xs
        h2, (cc, st) = S.mamba_block_decode(lp, h2, cfg, cc, st)
        return (h2,), (cc, st)

    for gi in range(n_groups):
        gp = jax.tree.map(lambda a, gi=gi: a[gi], params["main"])
        (h,), (cg, sg) = jax.lax.scan(mamba_body, (h,),
                                      (gp, conv[gi], state[gi]))
        conv = conv.at[gi].set(cg)
        state = state.at[gi].set(sg)
        layer_cache = ((skp[gi], svp[gi], table) if sksc is None
                       else (skp[gi], svp[gi], table, sksc[gi], svsc[gi]))
        h, new_kv = L.block_apply(
            shared, h, positions, cfg, causal=False, kv_lens=pos + 1,
            q_offset=pos, cache=layer_cache, cache_pos=pos)
        skp = skp.at[gi].set(new_kv[0])
        svp = svp.at[gi].set(new_kv[1])
        if sksc is not None:
            sksc = sksc.at[gi].set(new_kv[2])
            svsc = svsc.at[gi].set(new_kv[3])
    cache["conv"], cache["state"] = conv, state
    cache["shared_k_pages"], cache["shared_v_pages"] = skp, svp
    if sksc is not None:
        cache["shared_k_pages_scale"] = sksc
        cache["shared_v_pages_scale"] = svsc

    if "tail" in params:
        (h,), (tc, ts) = jax.lax.scan(
            mamba_body, (h,), (params["tail"], cache["tail_conv"],
                               cache["tail_state"]))
        cache["tail_conv"], cache["tail_state"] = tc, ts
    return h, cache


def decode(params, cfg, batch, cache):
    token = batch["token"]
    pos = cache["pos"]
    positions = pos[:, None]
    x = L.embed(params["embed"], token, cfg)
    if "shared_k_pages" in cache:
        h, cache = _decode_paged(params, cfg, x, positions, cache)
    else:
        h, cache = _groups_cached(params, cfg, x, positions, cache, lens=None,
                                  q_offset=pos, cache_pos=pos, causal=False,
                                  decode_step=True)
    cache["pos"] = pos + 1
    h = L.apply_norm(params["final_norm"], h, cfg)
    return L.unembed(params["embed"], h, cfg)[:, 0], cache
