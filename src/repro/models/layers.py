"""Shared model building blocks (pure functional, dtype-disciplined).

Conventions
-----------
* every ``*_init`` returns a params pytree; the matching ``*_axes`` returns a
  tree of logical-axis tuples (one name or None per array dim) consumed by
  ``repro.dist.sharding`` — the mesh-agnostic resolution is the cluster-scale
  VLA story (DESIGN.md §2).
* master params live in ``cfg.param_dtype``; matmul inputs are cast to
  ``cfg.compute_dtype``; norms/softmax/rope run in f32.
* stacked layers carry a leading "layers" axis and are consumed by lax.scan.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import paging as PG
from repro.dist import sharding as SH
from repro.kernels.flash_attention import flash_attention


def shard_act(cfg, x, axes):
    """Activation sharding constraint (Megatron-TP pattern), opt-in via
    cfg.act_shard; no-op outside dist.sharding.use_mesh_rules."""
    if cfg.act_shard == "none":
        return x
    return SH.constrain(x, axes)


def shard_pool(cfg, pool):
    """KV page-pool sharding constraint: heads over the model axis, the page
    and page-size dims whole (pools are addressed by table gathers — a split
    page dim would turn every gather into a collective).  Same opt-in as
    shard_act; a head count that doesn't divide the axis replicates."""
    if cfg.act_shard == "none":
        return pool
    nd = pool.ndim
    return SH.constrain(pool, (None,) * (nd - 3) + ("kv_heads", None, None))


def shard_scale(cfg, scale):
    """Per-slot scale-pool sharding constraint: ``lead + (P, Hkv, ps)`` rides
    its pool — heads over the model axis, page dims whole."""
    if cfg.act_shard == "none":
        return scale
    nd = scale.ndim
    return SH.constrain(scale, (None,) * (nd - 2) + ("kv_heads", None))


def shard_residual(cfg, x):
    """Megatron-SP: residual stream (B, S, d) sharded over the model axis on
    the seq dim between blocks (only under act_shard='tp_sp').  The remat-
    saved per-layer carry shrinks by the TP degree; XLA inserts the
    all-gather/reduce-scatter pair at the qkv/mlp boundaries."""
    if cfg.act_shard != "tp_sp":
        return x
    return SH.constrain(x, ("batch", "act_seq", None))

# ---------------------------------------------------------------------------
# dtype helpers
# ---------------------------------------------------------------------------

def pdt(cfg):
    return jnp.dtype(cfg.param_dtype)


def cdt(cfg):
    return jnp.dtype(cfg.compute_dtype)


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(cfg, d):
    p = {"scale": jnp.ones((d,), pdt(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), pdt(cfg))
    return p


def norm_axes(cfg):
    ax = {"scale": ("embed",)}
    if cfg.norm == "layernorm":
        ax["bias"] = ("embed",)
    return ax


def apply_norm(p, x, cfg):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def _rms_headdim(x, eps=1e-6):
    """qk-norm: rmsnorm over the head dim (no learned scale for simplicity)."""
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
            ).astype(x.dtype)


# ---------------------------------------------------------------------------
# rope
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float, rotary_frac: float = 1.0):
    """x: (B, H, S, Dh); positions: (B, S) int32.  Half-split convention."""
    dh = x.shape[-1]
    rd = int(dh * rotary_frac)
    rd -= rd % 2
    if rd == 0:
        return x
    half = rd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None, :, None] * freqs  # (B,1,S,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:rd].astype(jnp.float32)
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rot.astype(x.dtype), x[..., rd:]], axis=-1)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embed_init(key, cfg):
    v, d = cfg.padded_vocab, cfg.d_model
    k1, k2 = jax.random.split(key)
    p = {"tok": _normal(k1, (v, d), d ** -0.5, pdt(cfg))}
    if not cfg.tie_embeddings:
        p["unembed"] = _normal(k2, (d, v), d ** -0.5, pdt(cfg))
    return p


def embed_axes(cfg):
    ax = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        ax["unembed"] = ("embed", "vocab")
    return ax


def embed(p, ids, cfg):
    x = jnp.take(p["tok"], ids, axis=0).astype(cdt(cfg))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdt(cfg))
    return shard_act(cfg, x, ("batch", None, None))


def unembed(p, x, cfg):
    w = (p["tok"].T if cfg.tie_embeddings else p["unembed"]).astype(cdt(cfg))
    logits = jnp.einsum("bsd,dv->bsv", x.astype(cdt(cfg)), w)
    logits = shard_act(cfg, logits, ("batch", None, "act_vocab"))
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits.astype(jnp.float32) / c) * c
        logits = logits.astype(cdt(cfg))
    if cfg.padded_vocab != cfg.vocab_size:
        # padded slots are dead: mask so losses/samplers never pick them
        lane = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(lane >= cfg.vocab_size, jnp.asarray(-1e30, logits.dtype),
                           logits)
    return logits


# ---------------------------------------------------------------------------
# mlp
# ---------------------------------------------------------------------------

def mlp_init(key, cfg, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_down": _normal(ks[2], (f, d), f ** -0.5, pdt(cfg))}
    if cfg.activation in ("swiglu", "geglu"):
        p["w_gate"] = _normal(ks[0], (d, f), d ** -0.5, pdt(cfg))
        p["w_up"] = _normal(ks[1], (d, f), d ** -0.5, pdt(cfg))
    else:
        p["w_up"] = _normal(ks[1], (d, f), d ** -0.5, pdt(cfg))
    if cfg.use_bias:
        p["b_up"] = jnp.zeros((f,), pdt(cfg))
        p["b_down"] = jnp.zeros((d,), pdt(cfg))
    return p


def mlp_axes(cfg, d_ff: Optional[int] = None):
    # w_down's ff dim feeds the down-proj contraction ("mlp_in", see wo)
    ax = {"w_down": ("mlp_in", "embed")}
    if cfg.activation in ("swiglu", "geglu"):
        ax["w_gate"] = ("embed", "mlp")
        ax["w_up"] = ("embed", "mlp")
    else:
        ax["w_up"] = ("embed", "mlp")
    if cfg.use_bias:
        ax["b_up"] = ("mlp",)
        ax["b_down"] = ("embed",)
    return ax


def mlp(p, x, cfg):
    act_axes = ("batch",) + (None,) * (x.ndim - 2) + ("act_mlp",)
    xc = x.astype(cdt(cfg))
    up = shard_act(cfg, xc @ p["w_up"].astype(cdt(cfg)), act_axes)
    if cfg.use_bias:
        up = up + p["b_up"].astype(cdt(cfg))
    if cfg.activation == "swiglu":
        up = jax.nn.silu(shard_act(cfg, xc @ p["w_gate"].astype(cdt(cfg)),
                                   act_axes)) * up
    elif cfg.activation == "geglu":
        up = jax.nn.gelu(shard_act(cfg, xc @ p["w_gate"].astype(cdt(cfg)),
                                   act_axes), approximate=True) * up
    else:
        up = jax.nn.gelu(up, approximate=True)
    # the down-proj input (see act_attn_in): training keeps it sharded and
    # psums the partial dots; serving gathers the (small) intermediate here
    # so the contraction runs whole — bitwise-identical logits, and the
    # collective is a few KB of activations, not the up-proj weights
    up = shard_act(cfg, up,
                   ("batch",) + (None,) * (x.ndim - 2) + ("act_mlp_in",))
    out = up @ p["w_down"].astype(cdt(cfg))
    if cfg.use_bias:
        out = out + p["b_down"].astype(cdt(cfg))
    out = shard_act(cfg, out, ("batch",) + (None,) * (x.ndim - 1))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (self / cross, cached / uncached, local / global)
# ---------------------------------------------------------------------------

def attn_init(key, cfg):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qo, kvo = cfg.n_heads * hd, cfg.n_kv_heads * hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": _normal(ks[0], (d, qo), d ** -0.5, pdt(cfg)),
        "wk": _normal(ks[1], (d, kvo), d ** -0.5, pdt(cfg)),
        "wv": _normal(ks[2], (d, kvo), d ** -0.5, pdt(cfg)),
        "wo": _normal(ks[3], (qo, d), qo ** -0.5, pdt(cfg)),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((qo,), pdt(cfg))
        p["bk"] = jnp.zeros((kvo,), pdt(cfg))
        p["bv"] = jnp.zeros((kvo,), pdt(cfg))
        p["bo"] = jnp.zeros((d,), pdt(cfg))
    return p


def attn_axes(cfg):
    # wo's first dim feeds the out-proj CONTRACTION: it gets its own logical
    # name so serving can replicate it (a contraction split psums partial
    # dots, which reassociates the f32 sum — bitwise-identical serving
    # gathers the merged heads and runs the full dot instead)
    ax = {"wq": ("embed", "heads"), "wk": ("embed", "kv_heads"),
          "wv": ("embed", "kv_heads"), "wo": ("heads_in", "embed")}
    if cfg.use_bias:
        ax.update({"bq": ("heads",), "bk": ("kv_heads",), "bv": ("kv_heads",),
                   "bo": ("embed",)})
    return ax


def _split_heads(t, n_heads, hd):
    b, s, _ = t.shape
    return t.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)


def _merge_heads(t):
    b, h, s, hd = t.shape
    return t.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


def update_kv_cache(k_cache, v_cache, k_new, v_new, pos):
    """Write (B, Hkv, Snew, Dh) at per-row offsets pos (B,) into (B, Hkv, Smax, Dh)."""
    def row(kc, vc, kn, vn, p0):
        kc = jax.lax.dynamic_update_slice(kc, kn.astype(kc.dtype), (0, p0, 0))
        vc = jax.lax.dynamic_update_slice(vc, vn.astype(vc.dtype), (0, p0, 0))
        return kc, vc
    return jax.vmap(row)(k_cache, v_cache, k_new, v_new, pos)


def attention(p, x, positions, cfg, *,
              kv_x=None, causal=True, window=None, kv_lens=None,
              q_offset=None, cache=None, cache_pos=None, use_rope=True):
    """Returns (out, new_cache_kv_or_None).

    - ``kv_x``: cross-attention source (image/frame/encoder memory).
    - ``cache``: (k_cache, v_cache) of shape (B, Hkv, Smax, Dh); new K/V are
      written at ``cache_pos`` (B,) and attention runs over the cache.
    - ``window``: None | int | scalar array — dynamic sliding window, one
      predicated kernel for local AND global layers (DESIGN.md C2).
    """
    hd = cfg.resolved_head_dim
    xc = x.astype(cdt(cfg))
    src = xc if kv_x is None else kv_x.astype(cdt(cfg))

    q = xc @ p["wq"].astype(cdt(cfg))
    k = src @ p["wk"].astype(cdt(cfg))
    v = src @ p["wv"].astype(cdt(cfg))
    if cfg.use_bias:
        q, k, v = (q + p["bq"].astype(cdt(cfg)), k + p["bk"].astype(cdt(cfg)),
                   v + p["bv"].astype(cdt(cfg)))
    q = shard_act(cfg, _split_heads(q, cfg.n_heads, hd),
                  ("batch", "act_heads", None, None))
    k = shard_act(cfg, _split_heads(k, cfg.n_kv_heads, hd),
                  ("batch", "act_kv_heads", None, None))
    v = shard_act(cfg, _split_heads(v, cfg.n_kv_heads, hd),
                  ("batch", "act_kv_heads", None, None))

    if cfg.qk_norm:
        q, k = _rms_headdim(q), _rms_headdim(k)
    if use_rope and kv_x is None:
        q = rope(q, positions, cfg.rope_theta, cfg.partial_rotary_factor)
        k = rope(k, positions, cfg.rope_theta, cfg.partial_rotary_factor)

    new_cache = None
    page_table = None
    k_sc = v_sc = None
    if cache is not None:
        if len(cache) in (3, 5):
            # paged cache (k_pool, v_pool, page_table[, k_scale, v_scale]):
            # scatter-store the new token into the lane's tail page; attention
            # gathers K/V blocks through the page table (SVE §2.3.3).  The
            # 5-tuple is a QUANTIZED cache: the scatter truncates to the
            # narrow pool dtype (per-slot absmax scale) and the gather widens
            # in register.  Decode-only (Snew == 1).
            k_pool, v_pool, page_table = cache[:3]
            ps = k_pool.shape[2]
            page_col = jnp.clip(cache_pos // ps, 0, page_table.shape[1] - 1)
            page_ids = jnp.take_along_axis(page_table, page_col[:, None],
                                           axis=1)[:, 0]
            off = cache_pos % ps
            if len(cache) == 5:
                k_sc, v_sc = cache[3], cache[4]
                k_pool, k_sc = PG.scatter_page_q(k_pool, k_sc, page_ids, off,
                                                 k[:, :, 0, :])
                v_pool, v_sc = PG.scatter_page_q(v_pool, v_sc, page_ids, off,
                                                 v[:, :, 0, :])
                k_sc, v_sc = shard_scale(cfg, k_sc), shard_scale(cfg, v_sc)
                k_pool = shard_pool(cfg, k_pool)
                v_pool = shard_pool(cfg, v_pool)
                k, v = k_pool, v_pool            # narrow: widened in-gather
                new_cache = (k_pool, v_pool, k_sc, v_sc)
            else:
                k_pool = shard_pool(cfg, PG.scatter_page(
                    k_pool, page_ids, off, k[:, :, 0, :]))
                v_pool = shard_pool(cfg, PG.scatter_page(
                    v_pool, page_ids, off, v[:, :, 0, :]))
                k, v = k_pool.astype(cdt(cfg)), v_pool.astype(cdt(cfg))
                new_cache = (k_pool, v_pool)
        else:
            k_cache, v_cache = cache
            k_cache, v_cache = update_kv_cache(k_cache, v_cache, k, v, cache_pos)
            k, v = k_cache.astype(cdt(cfg)), v_cache.astype(cdt(cfg))
            new_cache = (k_cache, v_cache)

    out = flash_attention(
        q, k, v, kv_lens=kv_lens, causal=causal, window=window,
        q_offset=q_offset, impl=cfg.attn_impl, page_table=page_table,
        k_scale=k_sc, v_scale=v_sc)
    out = shard_act(cfg, out, ("batch", "act_heads", None, None))
    # the out-proj input: under training rules act_attn_in rides "model"
    # (Megatron row-parallel, psum after the dot); under SERVE_RULES it
    # replicates, gathering the merged heads BEFORE the dot so the
    # contraction runs whole and logits stay bitwise-identical to 1-device
    merged = shard_act(cfg, _merge_heads(out).astype(cdt(cfg)),
                       ("batch", None, "act_attn_in"))
    out = merged @ p["wo"].astype(cdt(cfg))
    if cfg.use_bias:
        out = out + p["bo"].astype(cdt(cfg))
    out = shard_act(cfg, out, ("batch", None, None))
    return out.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# transformer block (pre-norm / cohere-parallel), dense MLP
# ---------------------------------------------------------------------------

def block_init(key, cfg, d_ff: Optional[int] = None):
    k1, k2 = jax.random.split(key)
    p = {"ln1": norm_init(cfg, cfg.d_model), "attn": attn_init(k1, cfg),
         "mlp": mlp_init(k2, cfg, d_ff)}
    if not cfg.parallel_block:
        p["ln2"] = norm_init(cfg, cfg.d_model)
    return p


def block_axes(cfg, d_ff: Optional[int] = None):
    ax = {"ln1": norm_axes(cfg), "attn": attn_axes(cfg),
          "mlp": mlp_axes(cfg, d_ff)}
    if not cfg.parallel_block:
        ax["ln2"] = norm_axes(cfg)
    return ax


def block_apply(p, x, positions, cfg, *, causal=True, window=None,
                kv_lens=None, q_offset=None, cache=None, cache_pos=None,
                kv_x=None, use_rope=True):
    x = shard_residual(cfg, x)
    h = apply_norm(p["ln1"], x, cfg)
    attn_out, new_cache = attention(
        p["attn"], h, positions, cfg, kv_x=kv_x, causal=causal, window=window,
        kv_lens=kv_lens, q_offset=q_offset, cache=cache, cache_pos=cache_pos,
        use_rope=use_rope)
    if cfg.parallel_block:                      # cohere: one norm, two branches
        out = x + attn_out + mlp(p["mlp"], h, cfg)
    else:
        h2 = x + attn_out
        out = h2 + mlp(p["mlp"], apply_norm(p["ln2"], h2, cfg), cfg)
    return shard_residual(cfg, out), new_cache


def remat_wrap(fn, cfg):
    """Activation checkpointing policy for scan-over-layers bodies."""
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def stack_init(key, n, init_one):
    """Stacked-layer init: vmap the per-layer init over n keys → leading L dim."""
    return jax.vmap(init_one)(jax.random.split(key, n))


def stack_axes(axes_one):
    return jax.tree.map(lambda ax: ("layers",) + tuple(ax), axes_one,
                        is_leaf=lambda x: isinstance(x, tuple))
