"""MoE decoder LMs (olmoe-1b-7b, moonshot-v1-16b-a3b / Moonlight).

GShard-style grouped dispatch: tokens are reshaped to (G groups, Tg, d) with
G sharded over the data axis and experts over the model axis; dispatch is
group-local (static shapes, no cross-shard counters) so pjit lowers the
expert exchange to all-to-alls.  Capacity overflow lanes are dropped — the
FFR analogue (kernels/moe_dispatch).  The position-assignment counters come
from the Pallas kernel (or its XLA oracle under pjit / dry-run).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.moe_dispatch import build_dispatch

from . import layers as L


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _moe_ffn_init(key, cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": L._normal(ks[0], (d, e), d ** -0.5, jnp.float32),
        "w_gate": L._normal(ks[1], (e, d, f), d ** -0.5, L.pdt(cfg)),
        "w_up": L._normal(ks[2], (e, d, f), d ** -0.5, L.pdt(cfg)),
        "w_down": L._normal(ks[3], (e, f, d), f ** -0.5, L.pdt(cfg)),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared"] = L.mlp_init(ks[4], cfg, d_ff=fs)
    return p


def _moe_ffn_axes(cfg):
    ax = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "mlp"),
        "w_up": ("experts", "embed", "mlp"),
        "w_down": ("experts", "mlp_in", "embed"),
    }
    if cfg.n_shared_experts:
        ax["shared"] = L.mlp_axes(cfg, d_ff=cfg.d_ff * cfg.n_shared_experts)
    return ax


def _moe_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.norm_init(cfg, cfg.d_model), "attn": L.attn_init(k1, cfg),
            "ln2": L.norm_init(cfg, cfg.d_model), "moe": _moe_ffn_init(k2, cfg)}


def _moe_block_axes(cfg):
    return {"ln1": L.norm_axes(cfg), "attn": L.attn_axes(cfg),
            "ln2": L.norm_axes(cfg), "moe": _moe_ffn_axes(cfg)}


def axes(cfg):
    ax = {"embed": L.embed_axes(cfg), "final_norm": L.norm_axes(cfg)}
    if cfg.first_k_dense:
        ax["dense_blocks"] = L.stack_axes(
            L.block_axes(cfg, d_ff=cfg.d_ff_dense or cfg.d_ff))
    ax["blocks"] = L.stack_axes(_moe_block_axes(cfg))
    return ax


def init(key, cfg):
    k_emb, k_dense, k_moe = jax.random.split(key, 3)
    params = {"embed": L.embed_init(k_emb, cfg),
              "final_norm": L.norm_init(cfg, cfg.d_model)}
    if cfg.first_k_dense:
        params["dense_blocks"] = L.stack_init(
            k_dense, cfg.first_k_dense,
            lambda k: L.block_init(k, cfg, d_ff=cfg.d_ff_dense or cfg.d_ff))
    n_moe = cfg.n_layers - cfg.first_k_dense
    params["blocks"] = L.stack_init(k_moe, n_moe, lambda k: _moe_block_init(k, cfg))
    return params, axes(cfg)


# ---------------------------------------------------------------------------
# the MoE FFN (GShard grouped dispatch/combine)
# ---------------------------------------------------------------------------

def capacity(cfg, tokens_per_group: int) -> int:
    c = math.ceil(tokens_per_group * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, ((c + 7) // 8) * 8)        # sublane-aligned


def moe_ffn(p, x, cfg):
    """x: (B, S, d) -> (y, metrics).  Groups G = cfg.moe_groups must divide B*S."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    g = cfg.moe_groups
    t = b * s
    assert t % g == 0, (t, g)
    tg = t // g
    cap = capacity(cfg, tg)
    cd = L.cdt(cfg)

    xt = x.reshape(g, tg, d)
    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)                               # (G,Tg,K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)    # renorm

    disp = jax.vmap(lambda i, w: build_dispatch(i, w, e, cap, impl="xla"))(
        ids.astype(jnp.int32), gates)

    # gather tokens into expert buffers: (G, E, C, d).  Serving pins the
    # gather OPERAND whole: reshaping (B,S,d) into groups folds the
    # data-sharded batch into the token axis, and the +1 drop-row makes it
    # unevenly sharded — GSPMD's partitioned gather over such a padded axis
    # does not reproduce the unsharded values bit-for-bit, so at serve time
    # both dispatch and combine gathers must run on whole buffers.
    xp = jnp.concatenate([xt, jnp.zeros((g, 1, d), xt.dtype)], axis=1)
    xp = L.shard_act(cfg, xp, ("batch", "act_experts_in", None))
    table = disp["token_table"].reshape(g, e * cap)
    xe = jnp.take_along_axis(xp, table[..., None].astype(jnp.int32), axis=1)
    xe = xe.reshape(g, e, cap, d).astype(cd)

    # expert computation (all-to-all boundary under EP).  The dispatch
    # gather's OUTPUT carries its own logical name: serving pins it
    # replicated so the take_along_axis above never partitions (GSPMD's
    # partitioned gather over these oddly-padded buffer axes does not
    # reproduce the unsharded values bit-for-bit); the expert einsums below
    # still shard over e via their weights, so expert FLOPs stay split.
    ea = ("batch", "act_experts", None, None)
    xe = L.shard_act(cfg, xe, ("batch", "act_experts_in", None, None))
    up = L.shard_act(cfg, jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(cd)), ea)
    gate = L.shard_act(cfg, jnp.einsum("gecd,edf->gecf", xe,
                                       p["w_gate"].astype(cd)), ea)
    hidden = jax.nn.silu(gate) * up
    ye = L.shard_act(cfg, jnp.einsum("gecf,efd->gecd", hidden,
                                     p["w_down"].astype(cd)), ea)

    # combine back to token order.  Same contract as the dispatch side:
    # serving gathers the expert outputs whole before the combine's
    # take_along_axis (the intended per-layer collective — a few KB of
    # activations); training keeps the expert dim sharded (EP combine)
    ye_flat = jnp.concatenate([ye.reshape(g, e * cap, d),
                               jnp.zeros((g, 1, d), ye.dtype)], axis=1)
    ye_flat = L.shard_act(cfg, ye_flat, ("batch", "act_experts_out", None))
    slot = disp["slot_of"].reshape(g, tg * k)
    contrib = jnp.take_along_axis(ye_flat, slot[..., None].astype(jnp.int32), axis=1)
    contrib = contrib.reshape(g, tg, k, d)
    y = jnp.sum(contrib * disp["gates"][..., None].astype(contrib.dtype), axis=2)
    y = L.shard_act(cfg, y, ("batch", None, None))

    if cfg.n_shared_experts:
        y = y + L.mlp(p["shared"], xt, cfg).astype(y.dtype)

    # aux metrics (Switch load-balance + router z-loss)
    onehot = jax.nn.one_hot(ids[..., 0], e, dtype=jnp.float32)  # top-1 fraction
    f_e = onehot.mean(axis=(0, 1))
    p_e = probs.mean(axis=(0, 1))
    lb = e * jnp.sum(f_e * p_e)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = jnp.sum(disp["dropped"]) / jnp.asarray(t * k, jnp.float32)
    metrics = {"lb_loss": lb, "router_z": z, "dropped_frac": dropped}
    return y.reshape(b, s, d).astype(x.dtype), metrics


def _moe_block_apply(p, x, positions, cfg, *, kv_lens=None, q_offset=None,
                     cache=None, cache_pos=None, causal=True):
    x = L.shard_residual(cfg, x)
    h = L.apply_norm(p["ln1"], x, cfg)
    attn_out, new_cache = L.attention(
        p["attn"], h, positions, cfg, causal=causal, kv_lens=kv_lens,
        q_offset=q_offset, cache=cache, cache_pos=cache_pos)
    h2 = x + attn_out
    y, metrics = moe_ffn(p["moe"], L.apply_norm(p["ln2"], h2, cfg), cfg)
    return L.shard_residual(cfg, h2 + y), metrics, new_cache


# ---------------------------------------------------------------------------
# model API
# ---------------------------------------------------------------------------

def train_logits(params, cfg, batch):
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    kv_lens = batch.get("lens")
    x = L.embed(params["embed"], tokens, cfg)

    if cfg.first_k_dense:
        def dense_body(h, lp):
            h, _ = L.block_apply(lp, h, positions, cfg, causal=True, kv_lens=kv_lens)
            return h, None
        x, _ = jax.lax.scan(L.remat_wrap(dense_body, cfg), x, params["dense_blocks"])

    def body(h, lp):
        h, metrics, _ = _moe_block_apply(lp, h, positions, cfg, kv_lens=kv_lens)
        return h, metrics

    h, metrics = jax.lax.scan(L.remat_wrap(body, cfg), x, params["blocks"])
    aux = {k: jnp.mean(v) for k, v in metrics.items()}
    h = L.apply_norm(params["final_norm"], h, cfg)
    return L.unembed(params["embed"], h, cfg), aux


def make_cache(cfg, batch_size: int, max_len: int, dtype=None):
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    shp = (batch_size, hkv, max_len, hd)
    return {
        "dense_k": jnp.zeros((max(cfg.first_k_dense, 1),) + shp, dtype),
        "dense_v": jnp.zeros((max(cfg.first_k_dense, 1),) + shp, dtype),
        "k": jnp.zeros((cfg.n_layers - cfg.first_k_dense,) + shp, dtype),
        "v": jnp.zeros((cfg.n_layers - cfg.first_k_dense,) + shp, dtype),
        "pos": jnp.zeros((batch_size,), jnp.int32),
    }


def cache_batch_axes(cfg):
    """Request-lane axis of each cache array (see repro.models.gather_lanes)."""
    return {"dense_k": 1, "dense_v": 1, "k": 1, "v": 1, "pos": 0}


# prefix sharing is OFF for MoE: grouped expert dispatch (capacity dropping)
# makes hidden states — and therefore cached K/V — depend on the batch
# composition of the donor's prefill, so a sharer reusing donor pages is not
# guaranteed bit-identical to its own cold prefill.  Paged layout itself is
# sound (the view reproduces whatever was cached).
PAGED_PREFIX_OK = False

# prefill() takes per-row pos0 offsets with all cross-chunk state in the KV
# cache; chunked prefill of ONE prompt matches whole prefill token-for-token
# whenever expert capacity does not drop (dispatch groups see different
# co-tokens per chunk, but slot values are per-token when nothing drops)
CHUNKED_PREFILL_OK = True
# expert capacity is shared across the batch: dropping (dead) lanes changes
# which tokens overflow an expert buffer, so bursts must run full-width
LANE_INDEPENDENT_DECODE = False


def paged_decode_ok(cfg):
    """decode() reads every layer stack's K/V through the page table (the
    dense first-k stack and the MoE stack share one page id space)."""
    return True


def paged_cache_spec(cfg):
    """Every KV tensor pages; one page id spans dense AND MoE layer stacks."""
    return {"dense_k": (max(cfg.first_k_dense, 1),),
            "dense_v": (max(cfg.first_k_dense, 1),),
            "k": (cfg.n_layers - cfg.first_k_dense,),
            "v": (cfg.n_layers - cfg.first_k_dense,)}


def make_paged_cache(cfg, batch_size: int, max_len: int, *, page_size: int,
                     pool_pages: int, dtype=None, page_dtype=None):
    from repro.core import paging as PG
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    cache = PG.alloc_pools(paged_cache_spec(cfg), pool_pages, page_size,
                           cfg.n_kv_heads, cfg.resolved_head_dim, dtype,
                           page_dtype=page_dtype)
    cache["page_table"] = jnp.zeros(
        (batch_size, PG.pages_needed(max_len, page_size)), jnp.int32)
    cache["pos"] = jnp.zeros((batch_size,), jnp.int32)
    return cache


def _run_cached(params, cfg, x, positions, *, kv_lens, q_offset, cache,
                cache_pos, causal):
    new_cache = dict(cache)
    if cfg.first_k_dense:
        def dense_body(carry, xs):
            h, = carry
            lp, kc, vc = xs
            h, (kc, vc) = L.block_apply(
                lp, h, positions, cfg, causal=causal, kv_lens=kv_lens,
                q_offset=q_offset, cache=(kc, vc), cache_pos=cache_pos)
            return (h,), (kc, vc)
        (x,), (dk, dv) = jax.lax.scan(
            dense_body, (x,),
            (params["dense_blocks"], cache["dense_k"], cache["dense_v"]))
        new_cache["dense_k"], new_cache["dense_v"] = dk, dv

    def body(carry, xs):
        h, = carry
        lp, kc, vc = xs
        h, _, (kc, vc) = _moe_block_apply(
            lp, h, positions, cfg, kv_lens=kv_lens, q_offset=q_offset,
            cache=(kc, vc), cache_pos=cache_pos, causal=causal)
        return (h,), (kc, vc)

    (h,), (k_new, v_new) = jax.lax.scan(
        body, (x,), (params["blocks"], cache["k"], cache["v"]))
    new_cache["k"], new_cache["v"] = k_new, v_new
    return h, new_cache


def prefill(params, cfg, batch, cache):
    tokens = batch["tokens"]
    b, s = tokens.shape
    lens = batch.get("lens")
    lens = jnp.full((b,), s, jnp.int32) if lens is None else jnp.asarray(lens, jnp.int32)
    pos0 = batch.get("pos0")                    # suffix prefill (prefix sharing)
    pos0 = jnp.zeros((b,), jnp.int32) if pos0 is None else jnp.asarray(pos0, jnp.int32)
    positions = pos0[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
    x = L.embed(params["embed"], tokens, cfg)
    h, cache = _run_cached(params, cfg, x, positions, kv_lens=pos0 + lens,
                           q_offset=pos0, cache=cache, cache_pos=pos0,
                           causal=True)
    cache["pos"] = pos0 + lens
    h = L.apply_norm(params["final_norm"], h, cfg)
    idx = jnp.clip(lens - 1, 0, s - 1)
    h_last = jnp.take_along_axis(h, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    return L.unembed(params["embed"], h_last[:, None], cfg)[:, 0], cache


def _decode_paged(params, cfg, x, positions, cache):
    """Native paged decode: each layer's attention gathers K/V pages through
    the table and scatter-stores the new token into the lane's tail page —
    no dense-view materialization (SVE §2.3.3 on the hot path).  Layers are
    unrolled so the per-layer ``dynamic_update_slice`` on the stacked pools
    aliases in place (no scan-ys double buffer)."""
    pos = cache["pos"]
    table = cache["page_table"]
    cache = dict(cache)
    h = x
    dus = jax.lax.dynamic_update_slice_in_dim
    if cfg.first_k_dense:
        kp, vp = cache["dense_k_pages"], cache["dense_v_pages"]
        ksc = cache.get("dense_k_pages_scale")
        vsc = cache.get("dense_v_pages_scale")
        for li in range(cfg.first_k_dense):
            lp = jax.tree.map(lambda a, li=li: a[li], params["dense_blocks"])
            layer_cache = ((kp[li], vp[li], table) if ksc is None
                           else (kp[li], vp[li], table, ksc[li], vsc[li]))
            h, new_kv = L.block_apply(
                lp, h, positions, cfg, causal=False, kv_lens=pos + 1,
                q_offset=pos, cache=layer_cache, cache_pos=pos)
            kp = dus(kp, new_kv[0][None], li, axis=0)
            vp = dus(vp, new_kv[1][None], li, axis=0)
            if ksc is not None:
                ksc = dus(ksc, new_kv[2][None], li, axis=0)
                vsc = dus(vsc, new_kv[3][None], li, axis=0)
        cache["dense_k_pages"], cache["dense_v_pages"] = kp, vp
        if ksc is not None:
            cache["dense_k_pages_scale"] = ksc
            cache["dense_v_pages_scale"] = vsc
    kp, vp = cache["k_pages"], cache["v_pages"]
    ksc = cache.get("k_pages_scale")
    vsc = cache.get("v_pages_scale")
    for li in range(cfg.n_layers - cfg.first_k_dense):
        lp = jax.tree.map(lambda a, li=li: a[li], params["blocks"])
        layer_cache = ((kp[li], vp[li], table) if ksc is None
                       else (kp[li], vp[li], table, ksc[li], vsc[li]))
        h, _, new_kv = _moe_block_apply(
            lp, h, positions, cfg, kv_lens=pos + 1, q_offset=pos,
            cache=layer_cache, cache_pos=pos, causal=False)
        kp = dus(kp, new_kv[0][None], li, axis=0)
        vp = dus(vp, new_kv[1][None], li, axis=0)
        if ksc is not None:
            ksc = dus(ksc, new_kv[2][None], li, axis=0)
            vsc = dus(vsc, new_kv[3][None], li, axis=0)
    cache["k_pages"], cache["v_pages"] = kp, vp
    if ksc is not None:
        cache["k_pages_scale"], cache["v_pages_scale"] = ksc, vsc
    return h, cache


def decode(params, cfg, batch, cache):
    token = batch["token"]
    pos = cache["pos"]
    positions = pos[:, None]
    x = L.embed(params["embed"], token, cfg)
    if "k_pages" in cache:
        h, cache = _decode_paged(params, cfg, x, positions, cache)
    else:
        h, cache = _run_cached(params, cfg, x, positions, kv_lens=pos + 1,
                               q_offset=pos, cache=cache, cache_pos=pos,
                               causal=False)
    cache["pos"] = pos + 1
    h = L.apply_norm(params["final_norm"], h, cfg)
    return L.unembed(params["embed"], h, cfg)[:, 0], cache
