"""Mamba2 (SSD) attention-free LM — mamba2-130m and the hybrid backbone.

Block: norm -> in_proj -> [z | xBC | dt] -> causal depthwise conv (xBC) ->
silu -> SSD scan (Pallas kernel / XLA oracle) -> gated RMSNorm(y * silu(z))
-> out_proj.  Decode keeps a (W-1)-tap conv cache + the (H, P, N) SSM state —
constant memory in sequence length, which is why the long_500k cells run for
this family (DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import ssd_decode_step, ssd_scan

from . import layers as L


def _conv_dim(cfg):
    return cfg.d_inner + 2 * cfg.ssm_state


def mamba_block_init(key, cfg):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    cdim = _conv_dim(cfg)
    ks = jax.random.split(key, 4)
    return {
        "norm": L.norm_init(cfg, d),
        "in_proj": L._normal(ks[0], (d, di + cdim + h), d ** -0.5, L.pdt(cfg)),
        "conv_w": L._normal(ks[1], (cfg.ssm_conv_width, cdim),
                            cfg.ssm_conv_width ** -0.5, L.pdt(cfg)),
        "conv_b": jnp.zeros((cdim,), L.pdt(cfg)),
        "A_log": jnp.zeros((h,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),   # softplus(-2) ~ 0.12
        "out_norm": {"scale": jnp.ones((di,), L.pdt(cfg))},
        "out_proj": L._normal(ks[2], (di, d), di ** -0.5, L.pdt(cfg)),
    }


def mamba_block_axes(cfg):
    return {
        "norm": L.norm_axes(cfg),
        "in_proj": ("embed", "ssm_inner"),
        "conv_w": (None, "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "out_norm": {"scale": ("ssm_inner",)},
        "out_proj": ("ssm_inner", "embed"),
    }


def _split_proj(cfg, proj):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + _conv_dim(cfg)]
    dt = proj[..., di + _conv_dim(cfg):]
    assert dt.shape[-1] == h
    return z, xbc, dt


def _causal_conv(xbc, w, b, tail=None):
    """Depthwise causal conv: xbc (B, S, C), w (W, C) -> (B, S, C).

    ``tail`` is the previous (W-1) PRE-conv taps (chunked-prefill resume);
    None means a fresh sequence (zero left-pad — bitwise identical to a zero
    tail, so one code path serves both)."""
    width = w.shape[0]
    if tail is None:
        pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([tail.astype(xbc.dtype), xbc], axis=1)
    out = jnp.zeros_like(xbc)
    for i in range(width):                       # width is 4: unrolled taps
        out = out + pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :], pad


def _gated_out_norm(p, y, z, cfg):
    """Mamba2 RMSNormGated: rmsnorm(y * silu(z)) * scale."""
    yf = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + cfg.norm_eps)
            * p["scale"].astype(jnp.float32)).astype(y.dtype)


def mamba_block(p, x, cfg, *, seq_lens=None, conv_init=None, state_init=None):
    """Full-sequence block.  Returns (out, (conv_tail, ssm_state)).

    ``conv_init`` (B, W-1, C) / ``state_init`` (B, H, P, N) resume a chunked
    prefill from the carried conv taps and SSM state; None (or all-zero
    inits, e.g. a fresh cache) is a fresh sequence — the two are bitwise
    identical, so serving can pass the cache unconditionally.  The conv tail
    returned (and cached) holds PRE-conv taps, matching what
    ``mamba_block_decode`` prepends to the next token's projection.
    """
    b, s, d = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    pdim = cfg.ssm_headdim
    cd = L.cdt(cfg)
    width = cfg.ssm_conv_width

    hin = L.apply_norm(p["norm"], x, cfg)
    proj = hin.astype(cd) @ p["in_proj"].astype(cd)
    z, xbc, dt = _split_proj(cfg, proj)
    conv_out, pre_taps = _causal_conv(xbc, p["conv_w"].astype(cd),
                                      p["conv_b"].astype(cd), tail=conv_init)
    xbc = jax.nn.silu(conv_out)
    x_in = xbc[..., :di].reshape(b, s, h, pdim)
    x_in = L.shard_act(cfg, x_in, ("batch", None, "act_ssm_heads", None))
    bmat = xbc[..., di:di + n]
    cmat = xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    dt = L.shard_act(cfg, dt, ("batch", None, "act_ssm_heads"))
    A = -jnp.exp(p["A_log"])

    y, hT = ssd_scan(x_in, dt, A, bmat, cmat, D=p["D"], seq_lens=seq_lens,
                     h0=state_init, chunk=cfg.ssm_chunk, impl=cfg.ssd_impl)
    y = L.shard_act(cfg, y, ("batch", None, "act_ssm_heads", None))
    y = y.reshape(b, s, di)
    y = _gated_out_norm(p["out_norm"], y, z, cfg)
    out = x + (y.astype(cd) @ p["out_proj"].astype(cd)).astype(x.dtype)

    # conv tail for serving: last (W-1) PRE-conv taps at each row's length
    # (pre_taps = [init | pre-conv xBC], so valid row length l ends at
    # pre_taps index (W-1)+l and the W-1 taps before it start at index l)
    if seq_lens is None:
        tail = pre_taps[:, s:, :]
    else:
        tail = jax.vmap(
            lambda xb, l: jax.lax.dynamic_slice(
                xb, (l, 0), (width - 1, xb.shape[-1])))(pre_taps,
                                                        jnp.asarray(seq_lens))
    return out, (tail, hT)


def mamba_block_decode(p, x_t, cfg, conv_cache, state):
    """One-token block.  x_t: (B, 1, d); conv_cache: (B, W-1, C); state f32."""
    b = x_t.shape[0]
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    pdim = cfg.ssm_headdim
    cd = L.cdt(cfg)

    hin = L.apply_norm(p["norm"], x_t, cfg)
    proj = hin.astype(cd) @ p["in_proj"].astype(cd)
    z, xbc, dt = _split_proj(cfg, proj)                   # (B, 1, *)
    window = jnp.concatenate([conv_cache, xbc.astype(conv_cache.dtype)], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(cd),
                          p["conv_w"].astype(cd)) + p["conv_b"].astype(cd)
    xbc_t = jax.nn.silu(conv_out)                         # (B, C)
    x_in = xbc_t[:, :di].reshape(b, h, pdim)
    bmat, cmat = xbc_t[:, di:di + n], xbc_t[:, di + n:]
    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])

    y, state = ssd_decode_step(x_in, dt_t, A, bmat, cmat, state, D=p["D"])
    y = y.reshape(b, 1, di)
    y = _gated_out_norm(p["out_norm"], y, z, cfg)
    out = x_t + (y.astype(cd) @ p["out_proj"].astype(cd)).astype(x_t.dtype)
    return out, (window[:, 1:, :], state)


# ---------------------------------------------------------------------------
# model API
# ---------------------------------------------------------------------------

def axes(cfg):
    return {"embed": L.embed_axes(cfg),
            "blocks": L.stack_axes(mamba_block_axes(cfg)),
            "final_norm": L.norm_axes(cfg)}


def init(key, cfg):
    k_emb, k_blocks = jax.random.split(key)
    params = {"embed": L.embed_init(k_emb, cfg),
              "blocks": L.stack_init(k_blocks, cfg.n_layers,
                                     lambda k: mamba_block_init(k, cfg)),
              "final_norm": L.norm_init(cfg, cfg.d_model)}
    return params, axes(cfg)


def train_logits(params, cfg, batch):
    tokens = batch["tokens"]
    seq_lens = batch.get("lens")
    x = L.embed(params["embed"], tokens, cfg)

    def body(h, lp):
        h, _ = mamba_block(lp, h, cfg, seq_lens=seq_lens)
        return h, None

    h, _ = jax.lax.scan(L.remat_wrap(body, cfg), x, params["blocks"])
    h = L.apply_norm(params["final_norm"], h, cfg)
    return L.unembed(params["embed"], h, cfg), {}


def make_cache(cfg, batch_size: int, max_len: int = 0, dtype=None):
    """SSM caches are length-independent: conv tail + state (+ pos)."""
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    lcount = cfg.n_layers
    return {
        "conv": jnp.zeros((lcount, batch_size, cfg.ssm_conv_width - 1,
                           _conv_dim(cfg)), dtype),
        "state": jnp.zeros((lcount, batch_size, cfg.n_ssm_heads,
                            cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
        "pos": jnp.zeros((batch_size,), jnp.int32),
    }


def cache_batch_axes(cfg):
    """Request-lane axis of each cache array (see repro.models.gather_lanes)."""
    return {"conv": 1, "state": 1, "pos": 0}


# prefill() resumes the scan from the cached conv taps + SSM state (a fresh
# cache is all-zero, which is bitwise identical to no carry), so chunked
# prefill is exact — provided chunk boundaries land on multiples of
# ssm_chunk so the chunk_step sequence matches the unchunked scan.
CHUNKED_PREFILL_OK = True
# decode has no cross-lane coupling: bursts may narrow to a lane prefix
LANE_INDEPENDENT_DECODE = True


def chunked_prefill_granularity(cfg) -> int:
    """Chunk boundaries must be multiples of the SSD scan chunk for the
    resumed scan to be bit-identical to the whole-prompt scan (identical
    chunk_step sequence; the dt=0 padded tail steps are exact identities)."""
    return int(cfg.ssm_chunk)


def paged_cache_spec(cfg):
    """SSM caches are length-independent — nothing to page (the degenerate
    case of the paged layout: zero pools, every lane's state is O(1))."""
    return {}


def make_paged_cache(cfg, batch_size: int, max_len: int = 0, *,
                     page_size: int = 0, pool_pages: int = 0, dtype=None,
                     page_dtype=None):
    raise ValueError(
        "ssm caches carry no per-token KV state; paging does not apply — "
        "serve this family with the dense cache (it is already O(1)/lane)")


def prefill(params, cfg, batch, cache):
    tokens = batch["tokens"]
    b, s = tokens.shape
    lens = batch.get("lens")
    lens = jnp.full((b,), s, jnp.int32) if lens is None else jnp.asarray(lens, jnp.int32)
    pos0 = batch.get("pos0")
    pos0 = jnp.zeros((b,), jnp.int32) if pos0 is None else jnp.asarray(pos0, jnp.int32)
    x = L.embed(params["embed"], tokens, cfg)

    # Resume from the cached carry unconditionally: a fresh cache is all-zero
    # conv taps / state, bitwise identical to the no-carry scan, so one trace
    # serves both whole-prompt and chunked (resumed) prefill.
    def body(h, xs):
        lp, cc, st = xs
        h, (tail, hT) = mamba_block(lp, h, cfg, seq_lens=lens,
                                    conv_init=cc, state_init=st)
        return h, (tail, hT)

    h, (tails, states) = jax.lax.scan(
        body, x, (params["blocks"], cache["conv"], cache["state"]))
    cache = dict(cache)
    cache["conv"] = tails.astype(cache["conv"].dtype)
    cache["state"] = states
    cache["pos"] = pos0 + lens
    h = L.apply_norm(params["final_norm"], h, cfg)
    idx = jnp.clip(lens - 1, 0, s - 1)
    h_last = jnp.take_along_axis(h, idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    return L.unembed(params["embed"], h_last[:, None], cfg)[:, 0], cache


def decode(params, cfg, batch, cache):
    token = batch["token"]
    x = L.embed(params["embed"], token, cfg)

    def body(carry, xs):
        h, = carry
        lp, cc, st = xs
        h, (cc, st) = mamba_block_decode(lp, h, cfg, cc, st)
        return (h,), (cc, st)

    (h,), (conv_new, state_new) = jax.lax.scan(
        body, (x,), (params["blocks"], cache["conv"], cache["state"]))
    cache = dict(cache)
    cache["conv"], cache["state"] = conv_new, state_new
    cache["pos"] = cache["pos"] + 1
    h = L.apply_norm(params["final_norm"], h, cfg)
    return L.unembed(params["embed"], h, cfg)[:, 0], cache
