"""`repro.obs` — zero-sync serve observability.

Three pieces, threaded through the serving stack at host-side seams only:

* :mod:`repro.obs.metrics` — a typed metrics registry (counters, gauges,
  series, fixed-bucket log2 histograms) that backs the scheduler's ``stats``
  and produces the exact summary dict ``BENCH_serving.json`` records.
* :mod:`repro.obs.trace` — a span/event recorder exporting Chrome/Perfetto
  ``trace_event`` JSON: round anatomy spans on the scheduler track plus one
  lifecycle track per request.
* :mod:`repro.obs.recorder` — the ``Obs`` facade the engine/scheduler/bench
  accept (``obs=...``), with a free no-op path when tracing is off.

The hard contract (tested in tests/test_obs.py): with tracing ON, served
tokens stay byte-identical and ``dispatches``/``host_syncs`` do not move —
observability reads host-side values the serve loop already holds and never
adds a device sync; with tracing OFF the recorder costs one predictable
branch per seam.
"""

from .metrics import (  # noqa: F401
    Counter,
    Gauge,
    LogHistogram,
    MetricsRegistry,
    Series,
    StatsView,
)
from .recorder import NULL_SPAN, Obs  # noqa: F401
from .trace import Tracer, validate_trace  # noqa: F401
