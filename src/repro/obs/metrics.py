"""Typed metrics registry: counters, gauges, series, log2 histograms.

The registry replaces the scheduler's free-form ``stats`` dict and the
serving benchmark's per-leg percentile math with one definition of each
aggregate.  Metric types:

* :class:`Counter` — monotonic int (``dispatches``, ``host_syncs``, ...).
* :class:`Gauge` — last-value float (pool occupancy right now).
* :class:`Series` — an appended per-round trace whose *snapshot* is its
  mean (``occupancy_trace`` → ``mean_occupancy``).
* :class:`LogHistogram` — streaming percentiles from FIXED log2 buckets;
  no sample list is ever stored, so recording is O(1) and memory is a few
  hundred int64s regardless of traffic.  Quantile error is bounded by the
  bucket width (``2 ** (1 / SUBDIV)`` relative), which tests pin against
  ``numpy.percentile``.

``MetricsRegistry.snapshot()`` flattens everything to the flat
``{key: number}`` dict shape ``BENCH_serving.json`` records per leg
(histograms emit ``{name}_p{q}_{unit}`` keys); ``stats_view()`` returns a
dict-like façade over the counters/series so existing ``stats["x"] += 1``
call sites and tests keep working unchanged.
"""

from __future__ import annotations

import math
from collections.abc import MutableMapping
from typing import Optional, Sequence

__all__ = ["Counter", "Gauge", "Series", "LogHistogram", "MetricsRegistry",
           "StatsView"]


class Counter:
    """Monotonic-ish integer counter (decrements are allowed for plan
    rollbacks — ``_unplan_pages`` un-counts a hit it optimistically took)."""

    __slots__ = ("name", "key", "value")

    def __init__(self, name: str, key: Optional[str] = None):
        self.name = name
        self.key = key or name
        self.value = 0

    def inc(self, n: int = 1):
        self.value += n

    def snapshot(self) -> dict:
        return {self.key: int(self.value)}


class Gauge:
    """Last-observed value."""

    __slots__ = ("name", "key", "value")

    def __init__(self, name: str, key: Optional[str] = None):
        self.name = name
        self.key = key or name
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)

    def snapshot(self) -> dict:
        return {self.key: self.value}


class Series:
    """Appended per-round trace; snapshots as its MEAN under ``key``.

    The underlying list stays reachable (``sched.stats["occupancy_trace"]``)
    because round-resolution traces are themselves an observability product
    — one float per scheduling round, bounded by the run length.
    """

    __slots__ = ("name", "key", "values")

    def __init__(self, name: str, key: Optional[str] = None):
        self.name = name
        self.key = key or name
        self.values: list = []

    def append(self, v: float):
        self.values.append(v)

    @property
    def mean(self) -> float:
        return float(sum(self.values) / len(self.values)) if self.values else 0.0

    def snapshot(self) -> dict:
        return {self.key: self.mean}


class LogHistogram:
    """Streaming percentile estimator over fixed log2 buckets.

    Positive samples land in bucket ``floor(log2(v) * SUBDIV)``: SUBDIV
    sub-buckets per octave give a relative resolution of ``2**(1/SUBDIV)``
    (~9% at the default 8).  Non-positive samples land in a dedicated
    zero bucket reported as 0.0.  ``percentile(q)`` is nearest-rank over
    the bucket counts, returning the hit bucket's geometric midpoint — so
    p50/p90/p99 cost an O(buckets) scan and NO stored samples, the
    property that lets the serve loop record per-round latencies without
    growing state.
    """

    SUBDIV = 8                       # sub-buckets per octave
    LO = -30                         # 2**-30 ≈ 1e-9 in the recording unit
    HI = 30                          # 2**30 ≈ 1e9

    __slots__ = ("name", "unit", "percentiles", "counts", "zero", "count",
                 "total")

    def __init__(self, name: str, *, unit: str = "ms",
                 percentiles: Sequence[int] = (50, 99)):
        self.name = name
        self.unit = unit
        self.percentiles = tuple(percentiles)
        n = (self.HI - self.LO) * self.SUBDIV
        self.counts = [0] * n
        self.zero = 0                # v <= 0 samples
        self.count = 0
        self.total = 0.0             # exact running sum (mean stays exact)

    def record(self, v: float):
        self.count += 1
        self.total += v
        if v <= 0.0:
            self.zero += 1
            return
        idx = math.floor(math.log2(v) * self.SUBDIV) - self.LO * self.SUBDIV
        self.counts[min(max(idx, 0), len(self.counts) - 1)] += 1

    def _bucket_mid(self, idx: int) -> float:
        return 2.0 ** ((idx + 0.5) / self.SUBDIV + self.LO)

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile (geometric bucket midpoint); 0.0 when
        empty."""
        if self.count == 0:
            return 0.0
        rank = max(int(math.ceil(q / 100.0 * self.count)), 1)
        if rank <= self.zero:
            return 0.0
        seen = self.zero
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                return self._bucket_mid(i)
        return self._bucket_mid(len(self.counts) - 1)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return {f"{self.name}_p{q}_{self.unit}": self.percentile(q)
                for q in self.percentiles}


class StatsView(MutableMapping):
    """Dict façade over a registry's counters and series.

    ``view["dispatches"] += 1`` hits the underlying :class:`Counter`;
    ``view["occupancy_trace"].append(x)`` hits the :class:`Series` list.
    This is what keeps every existing ``sched.stats[...]`` call site and
    test working while the registry owns the storage.
    """

    def __init__(self, registry: "MetricsRegistry"):
        self._registry = registry

    def _stats(self) -> dict:
        return {name: m for name, m in self._registry._metrics.items()
                if isinstance(m, (Counter, Series))}

    def __getitem__(self, name):
        m = self._stats()[name]
        return m.values if isinstance(m, Series) else m.value

    def __setitem__(self, name, value):
        m = self._registry._metrics.get(name)
        if isinstance(m, Counter):
            m.value = value
        elif isinstance(m, Series):
            m.values = list(value)
        else:
            self._registry.counter(name).value = value

    def __delitem__(self, name):
        raise TypeError("stats metrics cannot be deleted")

    def __iter__(self):
        return iter(self._stats())

    def __len__(self):
        return len(self._stats())

    def __repr__(self):
        return repr(dict(self))


class MetricsRegistry:
    """Name-keyed collection of metrics with one flat snapshot.

    ``counter``/``gauge``/``series``/``histogram`` are idempotent
    fetch-or-create (re-registering under the same name returns the live
    metric), so the scheduler and the bench can both name the metrics they
    touch without ordering constraints.
    """

    def __init__(self):
        self._metrics: dict = {}

    def _get_or_make(self, cls, name, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, key: Optional[str] = None) -> Counter:
        return self._get_or_make(Counter, name, key=key)

    def gauge(self, name: str, key: Optional[str] = None) -> Gauge:
        return self._get_or_make(Gauge, name, key=key)

    def series(self, name: str, key: Optional[str] = None) -> Series:
        return self._get_or_make(Series, name, key=key)

    def histogram(self, name: str, *, unit: str = "ms",
                  percentiles: Sequence[int] = (50, 99)) -> LogHistogram:
        return self._get_or_make(LogHistogram, name, unit=unit,
                                 percentiles=percentiles)

    def inc(self, name: str, n: int = 1):
        self.counter(name).inc(n)

    def observe(self, name: str, v: float, **kw):
        self.histogram(name, **kw).record(v)

    def get(self, name: str):
        return self._metrics.get(name)

    def stats_view(self) -> StatsView:
        return StatsView(self)

    def snapshot(self) -> dict:
        """Flat ``{key: number}`` dict over every registered metric — the
        per-leg summary shape ``BENCH_serving.json`` promises."""
        out: dict = {}
        for m in self._metrics.values():
            out.update(m.snapshot())
        return out
