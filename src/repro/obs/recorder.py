"""The ``Obs`` facade the serving stack threads through (``obs=...``).

One object bundles the two sinks — a :class:`~repro.obs.metrics.
MetricsRegistry` (always present; it backs ``scheduler.stats``) and an
optional :class:`~repro.obs.trace.Tracer` — behind no-op-cheap entry
points.  Every hook degrades to a single attribute test when tracing is
off: ``span`` returns the shared :data:`NULL_SPAN`, ``event``/``counter``/
``request_*`` return immediately.  That is the "tracing OFF costs nothing
measurable" half of the contract; the other half (tracing ON moves no
tokens and no ``dispatches``/``host_syncs``) holds because every hook
records only host-resident values.

``xla_annotations=True`` additionally wraps ``span(..., xla=True)`` seams
in ``jax.profiler.TraceAnnotation`` so a concurrently-captured XLA profile
(``jax.profiler.trace``) interleaves the device timeline with these spans.
"""

from __future__ import annotations

from typing import Optional

from .metrics import MetricsRegistry
from .trace import Tracer

__all__ = ["Obs", "NULL_SPAN"]


class _NullSpan:
    """Shared do-nothing context manager (the tracing-off fast path)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


def _trace_annotation(name: str):
    """A ``jax.profiler.TraceAnnotation`` (None when unavailable)."""
    try:
        from jax.profiler import TraceAnnotation
    except Exception:                                    # pragma: no cover
        return None
    return TraceAnnotation(name)


class Obs:
    """Observability handle: a metrics registry plus an optional tracer.

    Parameters
    ----------
    metrics: registry to record into (default: a fresh one — callers that
        want engine + scheduler + bench in one registry pass it explicitly).
    tracer: a :class:`Tracer` to record the span timeline into, or None
        (the default) for metrics-only operation.
    xla_annotations: wrap dispatch-seam spans in
        ``jax.profiler.TraceAnnotation`` so XLA device profiles interleave.
    """

    def __init__(self, metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 xla_annotations: bool = False):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer
        self.xla_annotations = xla_annotations

    @property
    def tracing(self) -> bool:
        return self.tracer is not None

    # ------------------------------------------------------------------
    # span/event hooks (no-ops without a tracer)
    # ------------------------------------------------------------------

    def span(self, name: str, xla: bool = False, **args):
        """Span on the serve-loop track; ``xla=True`` marks a dispatch seam
        eligible for the TraceAnnotation wrapper."""
        if self.tracer is None:
            return NULL_SPAN
        ann = (_trace_annotation(name)
               if xla and self.xla_annotations else None)
        return self.tracer.span(name, ann=ann, **args)

    def event(self, name: str, **args):
        if self.tracer is not None:
            self.tracer.instant(name, **args)

    def counter(self, name: str, value: float):
        if self.tracer is not None:
            self.tracer.counter(name, value)

    def request_begin(self, rid: int, **args):
        if self.tracer is not None:
            self.tracer.request_begin(rid, **args)

    def request_event(self, rid: int, name: str, **args):
        if self.tracer is not None:
            self.tracer.request_event(rid, name, **args)

    def request_end(self, rid: int, **args):
        if self.tracer is not None:
            self.tracer.request_end(rid, **args)

    def export(self, path: str) -> int:
        """Export the trace (0 events when tracing is off)."""
        return self.tracer.export(path) if self.tracer is not None else 0
