"""Span/event recorder exporting Chrome/Perfetto ``trace_event`` JSON.

One :class:`Tracer` records a serve run's timeline as two processes:

* pid 1, "serve loop" — the scheduler's round anatomy.  Every scheduling
  round is a ``round`` span on tid 0 nesting its phase spans (``plan`` /
  ``admit`` / ``dispatch`` / ``burst`` / ``harvest`` / ``compact`` /
  ``swap_out`` / ``swap_in`` / ``sync``), mirroring the round walk in
  docs/ARCHITECTURE.md §1.  Counter tracks (``occupancy``,
  ``pool_occupancy``) ride alongside as ``ph: "C"`` events.
* pid 2, "requests" — one lifecycle track per request (tid = rid): a
  ``req<rid>`` span opened at submit and closed at harvest (or at any other
  typed finish — cancel, deadline, shed), with instant events for
  ``admitted`` / ``first_token`` and the robustness arcs ``cancelled`` /
  ``preempted`` / ``resumed`` / ``deadline`` / ``shed``, plus
  page/prefix/session annotations in ``args``.  Lifecycle instants always
  land INSIDE the request's open span — ``validate_trace`` pins that.

Timestamps are host ``perf_counter_ns`` microseconds relative to the
tracer's birth; everything recorded is a value the serve loop already
holds on the host, so recording NEVER adds a device sync (the byte-identity
contract tests/test_obs.py pins).  Open ``chrome://tracing`` or
https://ui.perfetto.dev and load the exported file to inspect a round.
"""

from __future__ import annotations

import json
import time
from typing import Optional

__all__ = ["Tracer", "validate_trace", "PID_SERVE", "PID_REQUESTS"]

PID_SERVE = 1
PID_REQUESTS = 2


class _Span:
    """Context manager recording a B/E pair on the tracer (re-entrant per
    instance is NOT supported — each ``span()`` call makes a fresh one)."""

    __slots__ = ("_tr", "_name", "_tid", "_args", "_ann")

    def __init__(self, tr: "Tracer", name: str, tid: int, args: dict,
                 ann=None):
        self._tr = tr
        self._name = name
        self._tid = tid
        self._args = args
        self._ann = ann                 # optional jax.profiler.TraceAnnotation

    def __enter__(self):
        if self._ann is not None:
            self._ann.__enter__()
        self._tr._emit("B", self._name, self._tid, self._args)
        return self

    def __exit__(self, *exc):
        self._tr._emit("E", self._name, self._tid, None)
        if self._ann is not None:
            self._ann.__exit__(*exc)
        return False


class Tracer:
    """In-memory ``trace_event`` recorder (see module docstring)."""

    def __init__(self):
        self._t0 = time.perf_counter_ns()
        self.events: list = []
        self._open: dict = {}           # (pid, tid) -> open-span depth
        self._req_names: dict = {}      # rid -> track name (open tracks)

    # ------------------------------------------------------------------
    # recording
    # ------------------------------------------------------------------

    def _ts(self) -> float:
        return (time.perf_counter_ns() - self._t0) / 1e3   # µs

    def _emit(self, ph: str, name: Optional[str], tid: int, args,
              pid: int = PID_SERVE, **extra):
        ev = {"ph": ph, "ts": self._ts(), "pid": pid, "tid": tid}
        if name is not None:
            ev["name"] = name
        if args:
            ev["args"] = args
        ev.update(extra)
        if ph == "B":
            self._open[(pid, tid)] = self._open.get((pid, tid), 0) + 1
        elif ph == "E":
            self._open[(pid, tid)] = self._open.get((pid, tid), 0) - 1
        self.events.append(ev)

    def span(self, name: str, tid: int = 0, ann=None, **args) -> _Span:
        """B/E span on the serve-loop track (context manager)."""
        return _Span(self, name, tid, args or None, ann)

    def instant(self, name: str, tid: int = 0, **args):
        """Instant event on the serve-loop track."""
        self._emit("i", name, tid, args or None, s="t")

    def counter(self, name: str, value: float, tid: int = 0):
        """Counter-track sample (Perfetto renders these as a value track)."""
        self._emit("C", name, tid, {"value": value})

    # ------------------------------------------------------------------
    # per-request lifecycle tracks (pid 2, tid = rid)
    # ------------------------------------------------------------------

    def request_begin(self, rid: int, **args):
        name = f"req{rid}"
        self._req_names[rid] = name
        self._emit("B", name, rid, args or None, pid=PID_REQUESTS)

    def request_event(self, rid: int, name: str, **args):
        if rid in self._req_names:
            self._emit("i", name, rid, args or None, pid=PID_REQUESTS, s="t")

    def request_end(self, rid: int, **args):
        name = self._req_names.pop(rid, None)
        if name is not None:
            self._emit("E", name, rid, args or None, pid=PID_REQUESTS)

    # ------------------------------------------------------------------
    # export
    # ------------------------------------------------------------------

    def close(self):
        """Close any still-open spans/tracks (a trace exported mid-run must
        still validate: every B needs its E)."""
        for rid in list(self._req_names):
            self.request_end(rid, truncated=True)
        for (pid, tid), depth in list(self._open.items()):
            for _ in range(max(depth, 0)):
                self._emit("E", None, tid, None, pid=pid)

    def trace_events(self) -> list:
        """Metadata + recorded events (the ``traceEvents`` payload)."""
        meta = [
            {"ph": "M", "pid": PID_SERVE, "tid": 0, "name": "process_name",
             "args": {"name": "serve loop"}},
            {"ph": "M", "pid": PID_SERVE, "tid": 0, "name": "thread_name",
             "args": {"name": "scheduler"}},
            {"ph": "M", "pid": PID_REQUESTS, "tid": 0, "name": "process_name",
             "args": {"name": "requests"}},
        ]
        return meta + self.events

    def export(self, path: str) -> int:
        """Write Chrome/Perfetto ``trace_event`` JSON; returns the number of
        recorded (non-metadata) events."""
        self.close()
        with open(path, "w") as f:
            json.dump({"traceEvents": self.trace_events(),
                       "displayTimeUnit": "ms"}, f)
        return len(self.events)


def validate_trace(events: list) -> list:
    """Structural check of a ``trace_event`` list; returns error strings.

    Pinned properties (the schema subset Perfetto relies on): every B has a
    matching same-track E (proper nesting, all spans closed), per-track
    timestamps are monotonically non-decreasing, E names — when present —
    match their B, and request-lifecycle instants (pid 2) fall inside their
    request's open span — an ``admitted``/``cancelled``/``preempted`` landing
    on a closed track means the scheduler finished a request twice.
    Metadata (``ph: "M"``) events are exempt.
    """
    errors: list = []
    stacks: dict = {}
    last_ts: dict = {}
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph == "M":
            continue
        key = (ev.get("pid"), ev.get("tid"))
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"event {i}: missing/bad ts {ts!r}")
            continue
        if ts < last_ts.get(key, float("-inf")):
            errors.append(f"event {i}: ts {ts} not monotonic on track {key}")
        last_ts[key] = ts
        if ph == "i" and ev.get("pid") == PID_REQUESTS \
                and not stacks.get(key):
            errors.append(f"event {i}: lifecycle instant "
                          f"{ev.get('name')!r} outside any open request "
                          f"span on track {key}")
        if ph == "B":
            stacks.setdefault(key, []).append((i, ev.get("name")))
        elif ph == "E":
            stack = stacks.setdefault(key, [])
            if not stack:
                errors.append(f"event {i}: E with no open B on track {key}")
                continue
            j, bname = stack.pop()
            ename = ev.get("name")
            if ename is not None and bname is not None and ename != bname:
                errors.append(f"event {i}: E name {ename!r} closes B "
                              f"{bname!r} (event {j}) on track {key}")
        elif ph not in ("i", "C", "X"):
            errors.append(f"event {i}: unknown phase {ph!r}")
    for key, stack in stacks.items():
        for j, name in stack:
            errors.append(f"track {key}: span {name!r} (event {j}) "
                          "never closed")
    return errors
