"""AdamW with FSDP-friendly state layout (m/v mirror param shardings)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def adamw_update(grads, opt_state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    count = opt_state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * jnp.square(gf)
        mh = m / bc1
        vh = v / bc2
        step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_p = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}
