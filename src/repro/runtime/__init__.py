from .ft import FaultTolerantLoop, StragglerWatchdog  # noqa: F401
