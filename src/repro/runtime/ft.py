"""Fault tolerance: checkpoint/restart training loop + straggler watchdog.

Designed for the 1000-node regime, demonstrated at container scale:

* **Recovery**: the loop catches step failures (injected in tests; real-world:
  device loss, preemption), restores the last committed checkpoint, rebuilds
  the data stream at the restored step (the pipeline is stateless in step —
  data/pipeline.py), and continues.  Repeated failures back off and
  eventually re-raise.
* **Straggler watchdog**: per-step wall times feed an EWMA; steps slower than
  ``threshold x`` the EWMA are flagged.  At fleet scale the flag feeds the
  scheduler (drain + re-shard via the elastic restore path — checkpoint
  format is mesh-free); here it is surfaced in metrics and logs.
* **Elastic re-mesh**: ``restore_checkpoint(..., shardings=...)`` re-shards
  the mesh-free on-disk state onto whatever mesh the restart brings up
  (tested on 8→4-device submeshes in tests/test_ft.py).
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint

log = logging.getLogger("repro.ft")


@dataclass
class StragglerWatchdog:
    """EWMA step-time monitor; flags steps slower than threshold x EWMA."""
    alpha: float = 0.1
    threshold: float = 2.0
    warmup_steps: int = 5
    ewma: Optional[float] = None
    seen: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.seen += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = (self.seen > self.warmup_steps
                        and dt > self.threshold * self.ewma)
        if is_straggler:
            self.flagged.append((step, dt, self.ewma))
            log.warning("straggler: step %d took %.3fs (ewma %.3fs)",
                        step, dt, self.ewma)
        else:
            # stragglers do not poison the EWMA
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


class FaultTolerantLoop:
    """Checkpoint/restart training loop driver.

    train_step: (state, batch) -> (state, metrics)
    batch_fn:   step -> batch           (stateless; restart-safe)
    save_every: checkpoint cadence (async, atomic)
    """

    def __init__(self, train_step: Callable, batch_fn: Callable, *,
                 ckpt_dir: str, save_every: int = 50, max_retries: int = 3,
                 state_shardings=None):
        self.train_step = train_step
        self.batch_fn = batch_fn
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.max_retries = max_retries
        self.state_shardings = state_shardings
        self.ckpt = AsyncCheckpointer(ckpt_dir)
        self.watchdog = StragglerWatchdog()
        self.recoveries = 0

    def resume_or(self, init_state):
        step = latest_step(self.ckpt_dir)
        if step is None:
            return init_state, 0
        state, step = restore_checkpoint(self.ckpt_dir, init_state,
                                         shardings=self.state_shardings)
        log.info("resumed from step %d", step)
        return state, step

    def run(self, init_state, num_steps: int, *, metrics_cb=None,
            fault_injector=None):
        """Run to ``num_steps``, surviving step failures via restore."""
        state, start = self.resume_or(init_state)
        step = start
        retries = 0
        fault_step = -1          # retries reset only once we pass this step
        history = []
        while step < num_steps:
            try:
                if fault_injector is not None:
                    fault_injector(step)          # tests: raise here
                batch = self.batch_fn(step)
                t0 = time.time()
                state, metrics = self.train_step(state, batch)
                # block on the loss so step time is real, and NaN-check it
                loss = float(metrics["loss"])
                dt = time.time() - t0
                if loss != loss:
                    raise FloatingPointError(f"NaN loss at step {step}")
                self.watchdog.observe(step, dt)
                history.append((step, loss))
                if metrics_cb:
                    metrics_cb(step, metrics)
                step += 1
                if step > fault_step:
                    # genuine progress past the last failure point — a
                    # PERSISTENT fault must not be reset by replayed steps
                    retries = 0
                if step % self.save_every == 0:
                    self.ckpt.save(step, state)
            except Exception as e:  # noqa: BLE001 — recovery path
                self.recoveries += 1
                retries += 1
                fault_step = max(fault_step, step)
                log.warning("step %d failed (%r); restoring (retry %d/%d)",
                            step, e, retries, self.max_retries)
                if retries > self.max_retries:
                    raise
                self.ckpt.wait()
                ck = latest_step(self.ckpt_dir)
                if ck is not None:
                    state, step = restore_checkpoint(
                        self.ckpt_dir, init_state,
                        shardings=self.state_shardings)
                else:
                    state, step = init_state, 0
                time.sleep(0.01 * retries)        # backoff (scaled down)
        self.ckpt.wait()
        self.ckpt.save(step, state)
        self.ckpt.wait()
        return state, history
