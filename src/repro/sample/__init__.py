"""Per-lane predicated sampling: heterogeneous stochastic decoding as SVE
predicate algebra (§2.3.2 per-lane predication, §2.3.5 ordered reductions).

Layout: ``params`` (per-request spec + batched lane state with the cache's
lane interface), ``processors`` (vocab keep-predicates: top-k/top-p/min-p/
bans, penalty rewrites), ``sampler`` (the jit-safe ``sample`` entry point —
bit-exact argmax under the greedy predicate), ``rejection`` (distribution-
preserving speculative acceptance), ``numpy_ref`` (the O(V) scalar oracle).
"""

from .params import (  # noqa: F401
    GREEDY,
    SamplingParams,
    gather_lanes,
    greedy_state,
    is_all_greedy,
    lane_state,
    slot_update,
    split_keys,
)
from .rejection import residual_dist, speculative_accept  # noqa: F401
from .sampler import (  # noqa: F401
    categorical_probs,
    greedy_tokens,
    gumbel_argmax,
    process_logits,
    sample,
)
