"""O(V) pure-numpy reference of the sampler's processor semantics.

An independent, loop-written implementation of the same set semantics the
predicate-algebra sampler commits to — the oracle the property tests compare
masks and distributions against.  Deliberately NOT vectorized the same way:
top-k is "everything >= the k-th largest value", top-p is a sequential
accumulation over the descending stable sort (the scalar loop ``fadda``
is bit-identical to), min-p is a threshold against the max prob.
"""

from __future__ import annotations

import numpy as np


def ref_keep_mask(logits: np.ndarray, *, temperature: float = 1.0,
                  top_k: int = 0, top_p: float = 1.0,
                  min_p: float = 0.0) -> np.ndarray:
    """Keep-mask (V,) bool for ONE lane, sequential-reference semantics."""
    x = np.asarray(logits, np.float64)
    if temperature > 0:
        x = x / temperature
    v = x.shape[0]
    keep = np.ones((v,), bool)
    if top_k > 0:
        kth = np.sort(x)[::-1][min(top_k, v) - 1]
        keep &= x >= kth
    e = np.exp(x - x.max())
    probs = e / e.sum()
    if top_p < 1.0:
        # sort key is the (scaled) LOGIT, stable — same tie order as the
        # predicate-algebra implementation (monotone to probability order);
        # the first entry is kept unconditionally (non-empty partition)
        order = np.argsort(-x, kind="stable")
        acc = 0.0
        nucleus = np.zeros((v,), bool)
        for j, idx in enumerate(order):       # the scalar fadda loop
            if j > 0 and acc >= top_p:
                break
            nucleus[idx] = True
            acc += probs[idx]
        keep &= nucleus
    if min_p > 0.0:
        keep &= (probs >= min_p * probs.max()) | (probs >= probs.max())
    return keep


def ref_penalised(logits: np.ndarray, out_tokens, *,
                  repetition_penalty: float = 1.0,
                  presence_penalty: float = 0.0) -> np.ndarray:
    """Penalty-rewritten logits (V,) for ONE lane over its generated tokens."""
    x = np.asarray(logits, np.float64).copy()
    for t in set(int(t) for t in out_tokens):
        x[t] = x[t] / repetition_penalty if x[t] > 0 \
            else x[t] * repetition_penalty
        x[t] -= presence_penalty
    return x


def ref_probs(logits: np.ndarray, *, temperature: float = 1.0,
              top_k: int = 0, top_p: float = 1.0,
              min_p: float = 0.0) -> np.ndarray:
    """Normalized sampling distribution (V,) under the reference masks."""
    keep = ref_keep_mask(logits, temperature=temperature, top_k=top_k,
                         top_p=top_p, min_p=min_p)
    x = np.asarray(logits, np.float64)
    if temperature > 0:
        x = x / temperature
    x = np.where(keep, x, -np.inf)
    e = np.exp(x - x[keep].max())
    return e / e.sum()
