"""Per-lane sampling parameters and the batched sampler lane state.

A serving batch is a vector of request LANES (paper §2.3.4); every request
carries its own decoding distribution.  ``SamplingParams`` is the host-side
per-request spec; ``lane_state`` stacks specs into a dict of (B,)-shaped
arrays — the same layout discipline as the KV cache's lane interface
(``models.gather_lanes`` / ``slot_update``) — so sampler state rides the
engine's jitted decode carry and moves with its lane under admission
splicing and compaction, never with the batch.

The per-lane PRNG key is the determinism contract: a request's key chain is
a function of its OWN seed only (``jax.random.PRNGKey(seed)``, split once
per decode step the lane participates in), so its token stream depends on
(seed, prompt, params) and never on batch composition — the property the
scheduler bit-identity tests extend to stochastic decoding.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

#: dict keys of a lane state, with per-lane dtypes (all shape (B,) except key)
_FIELDS = (
    ("temperature", jnp.float32),
    ("top_k", jnp.int32),
    ("top_p", jnp.float32),
    ("min_p", jnp.float32),
    ("repetition_penalty", jnp.float32),
    ("presence_penalty", jnp.float32),
    ("greedy", jnp.bool_),
)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Decoding distribution for ONE request.

    ``greedy=True`` (the default) is bit-exact ``argmax`` over the raw
    logits — no processor, no PRNG consumption on the selected token value
    (keys still advance so a lane's chain position stays equal to its token
    count).  ``temperature <= 0`` is treated as greedy.  ``top_k <= 0``
    disables top-k; ``top_p >= 1`` disables nucleus; ``min_p <= 0`` disables
    min-p; penalties at their identity (1.0 / 0.0) are no-ops.
    """
    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0
    min_p: float = 0.0
    repetition_penalty: float = 1.0
    presence_penalty: float = 0.0
    seed: int = 0
    greedy: bool = True
    # key derivation: PRNGKey(seed), then fold_in(fold) when fold is set —
    # how broadcast lanes and engine-default fallbacks decorrelate WITHOUT
    # colliding with another request's explicit seed (fold_in(k, i) never
    # equals PRNGKey(j))
    fold: Optional[int] = None


#: the all-greedy spec (what a request without SamplingParams decodes with)
GREEDY = SamplingParams()


@functools.lru_cache(maxsize=4096)
def _seed_key(seed: int, fold: Optional[int]) -> np.ndarray:
    # PRNGKey/fold_in are tiny jitted computations: memoize per (seed, fold)
    # so repeat admissions of a request (or the same spec) cost zero device
    # dispatches on the serve loop's host path
    k = jax.random.PRNGKey(seed)
    if fold is not None:
        k = jax.random.fold_in(k, fold)
    return np.asarray(k)


def _spec_key(spec: SamplingParams) -> np.ndarray:
    return _seed_key(int(spec.seed),
                     None if spec.fold is None else int(spec.fold))


def lane_state(specs: Union[None, SamplingParams,
                            Sequence[Optional[SamplingParams]]],
               b: int) -> dict:
    """Stack per-request specs into a batched lane state of ``b`` lanes.

    ``specs`` may be None (all lanes greedy), a single ``SamplingParams``
    (broadcast to every lane; each lane's key is decorrelated by folding
    the lane index unless the spec already pins a ``fold``), or a sequence
    of per-request specs (None entries mean greedy) — the admission path.
    Rows past the specs (padded admission sub-batches) are greedy with a
    zero key.
    """
    if specs is None:
        return greedy_state(b)
    if isinstance(specs, SamplingParams):
        specs = [specs if specs.fold is not None
                 else dataclasses.replace(specs, fold=i) for i in range(b)]
    if len(specs) > b:
        raise ValueError(f"{len(specs)} sampling specs for {b} lanes")
    if all(s is None for s in specs):
        return greedy_state(b)
    rows = [s if s is not None else GREEDY for s in specs]
    keys = np.stack([_spec_key(s) for s in rows] +
                    [np.zeros((2,), np.uint32)] * (b - len(rows)))
    rows = rows + [GREEDY] * (b - len(rows))
    return _stack(rows, keys)


def _stack(rows: Sequence[SamplingParams], keys: np.ndarray) -> dict:
    # host-side (numpy) leaves on purpose: lane states are assembled on the
    # scheduler's planning path every admission round, and eager jnp
    # conversion here would cost one device dispatch PER FIELD per round —
    # the jit boundary the state is passed into transfers them in one go
    state = {name: np.asarray([getattr(r, name) for r in rows],
                              np.dtype(dtype))
             for name, dtype in _FIELDS}
    # temperature <= 0 is greedy by definition: fold it into the flag so the
    # sampler's per-lane select is a single predicate
    state["greedy"] = state["greedy"] | (state["temperature"] <= 0.0)
    state["key"] = np.asarray(keys, np.uint32)
    return state


@functools.lru_cache(maxsize=256)
def _greedy_state_cached(b: int) -> tuple:
    st = _stack([GREEDY] * b, np.zeros((b, 2), np.uint32))
    return tuple(st.items())


def greedy_state(b: int) -> dict:
    """All-greedy lane state (zero keys: greedy lanes never read them).
    Memoized per lane count — all-greedy admission (the common case) reuses
    one host-side state instead of restacking it every round."""
    return dict(_greedy_state_cached(b))


def is_all_greedy(state: dict) -> bool:
    """Host-side query (concrete states only): every lane greedy?"""
    return bool(np.asarray(state["greedy"]).all())


# ----------------------------------------------------------------------
# lane permutation — the same contract as the cache lane interface
# ----------------------------------------------------------------------

def gather_lanes(state: dict, lanes) -> dict:
    """Permute/select sampler lanes (SVE ``compact``-style index gather):
    out lane i takes the state of input lane ``lanes[i]``.  jit-safe."""
    lanes = jnp.asarray(lanes, jnp.int32)
    return {k: jnp.take(v, lanes, axis=0) for k, v in state.items()}


def slot_update(state: dict, lanes, sub: dict) -> dict:
    """Splice ``sub`` (lane count == len(lanes)) into ``state`` at ``lanes``
    via in-place ``.at[].set`` scatters — the admission path.  jit-safe."""
    lanes = jnp.asarray(lanes, jnp.int32)
    # states assembled on the host path carry numpy leaves; .at needs jax
    return {k: jnp.asarray(v).at[lanes].set(sub[k].astype(v.dtype))
            for k, v in state.items()}


def split_keys(state: dict):
    """Advance every lane's PRNG chain one step: returns (new_state, subkeys).

    One split per decode step per lane — a lane's chain position therefore
    equals the number of steps since its admission, which for a live lane is
    its committed token count: the chain is batch-composition independent.
    """
    ks = jax.vmap(jax.random.split)(state["key"])       # (B, 2, 2)
    return dict(state, key=ks[:, 0]), ks[:, 1]
