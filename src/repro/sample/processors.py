"""Logit processors as SVE predicate algebra over the vocab axis.

Each processor either rewrites logits under a predicate (penalties — merging
predication, §2.3.2) or GENERATES a keep-predicate over the vocabulary
(top-k / top-p / min-p / token bans).  Predicates compose by AND; masked-out
vocab entries read as -inf so the final categorical (or argmax) only sees
the active partition.  Everything here is jit-safe, batched over lanes on
the leading axis, and traces into the engine's decode while-loop.

The top-p cutoff is the paper's serialized-reduction idiom (§2.3.5): sort
probabilities descending, accumulate with the strictly-ordered ``fadda``
prefix sums (``core.reductions.fadda_scan`` — bit-identical to the scalar
loop, so the cutoff never moves across vector lengths or backends), and the
keep-set is a ``whilelt``-shaped monotone prefix predicate in sorted order,
scattered back through the sort permutation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import reductions as R

Array = jax.Array

#: additive identity of the masked vocab partition
NEG_INF = float("-inf")


def apply_penalties(logits: Array, out_tokens: Array, n_out: Array,
                    repetition_penalty: Array,
                    presence_penalty: Array) -> Array:
    """Repetition/presence penalties over each lane's OWN output buffer.

    ``out_tokens`` (B, T) is the lane's generated-token buffer and ``n_out``
    (B,) its committed count — the "seen" vocab predicate is a scatter-store
    of the first ``n_out`` tokens (§2.3.3 gather/scatter over the lane's
    history, never the batch's), so the penalty depends only on the lane's
    own stream.  HF semantics: seen ∧ logit>0 → logit/r, seen ∧ logit<=0 →
    logit·r; presence subtracts a constant from seen tokens.
    """
    b, v = logits.shape
    t = out_tokens.shape[1]
    rows = jnp.arange(b)[:, None]
    # positions >= n_out are routed out of bounds and dropped: stale buffer
    # contents from a previous lane occupant can never leak into the predicate
    j = jnp.arange(t, dtype=jnp.int32)[None, :]
    cols = jnp.where(j < n_out[:, None], out_tokens, v)
    seen = jnp.zeros((b, v), bool).at[rows, cols].set(True, mode="drop")
    r = repetition_penalty[:, None]
    pen = jnp.where(logits > 0, logits / r, logits * r)
    out = jnp.where(seen, pen, logits)
    return out - jnp.where(seen, presence_penalty[:, None], 0.0)


def temperature_scale(logits: Array, temperature: Array) -> Array:
    """Divide by per-lane temperature; non-positive temperatures pass through
    unscaled (those lanes are greedy — the flag is folded in ``lane_state``)."""
    t = jnp.where(temperature > 0, temperature, 1.0)
    return logits / t[:, None]


def top_k_pred(logits: Array, k: Array) -> Array:
    """Keep-predicate of top-k filtering: active where logit >= the k-th
    largest value of the lane (``smaxv``-style threshold, set semantics:
    ties at the threshold stay active).  k <= 0 disables (all active).
    A view of ``keep_pred`` with the other filters disabled."""
    b = logits.shape[0]
    return keep_pred(logits, k, jnp.ones((b,), jnp.float32),
                     jnp.zeros((b,), jnp.float32))


def top_p_pred(logits: Array, top_p: Array, *, ordered: bool = True) -> Array:
    """Keep-predicate of nucleus (top-p) filtering.

    The smallest prefix of the sorted vocab whose mass reaches ``top_p``:
    entries are sorted by descending scaled logit (stable — deterministic
    tie order, and monotone to probability order), probabilities are
    accumulated in strict element order with ``fadda_scan`` (``ordered=
    False`` falls back to ``jnp.cumsum``), and the keep-set is the
    ``whilelt``-shaped predicate  exclusive_prefix_mass < top_p  — a
    monotone prefix in sorted order (the top-1 token is always active),
    scattered back to vocab order through the sort permutation.
    ``top_p >= 1`` disables (all active).  A view of ``keep_pred``.
    """
    b = logits.shape[0]
    return keep_pred(logits, jnp.zeros((b,), jnp.int32), top_p,
                     jnp.zeros((b,), jnp.float32), ordered=ordered)


def min_p_pred(logits: Array, min_p: Array) -> Array:
    """Keep-predicate of min-p filtering: active where prob >= min_p times
    the lane's max prob.  min_p <= 0 disables (all active).  A view of
    ``keep_pred``."""
    b = logits.shape[0]
    return keep_pred(logits, jnp.zeros((b,), jnp.int32),
                     jnp.ones((b,), jnp.float32), min_p)


def ban_pred(vocab_size: int, banned_ids) -> Array:
    """Static keep-predicate banning ``banned_ids`` (constrained decoding:
    the complement of the banned set is the active vocab partition)."""
    keep = jnp.ones((vocab_size,), bool)
    banned = jnp.asarray(banned_ids, jnp.int32)
    return keep.at[banned].set(False)


def stop_sequence_pred(vocab_size: int, last_token: Array,
                       stop_bigrams) -> Array:
    """Per-lane keep-predicate suppressing the completion of two-token stop
    sequences: where ``last_token[b]`` equals a bigram's first token, the
    bigram's second token is masked out of lane b's vocab partition.

    ``stop_bigrams`` is a static (N, 2) int sequence.  This is predicate
    *generation* from lane history — the constrained-decoding shape of
    §2.3.2 — kept deliberately minimal (longer sequences compose by
    chaining against the output buffer the same way).
    """
    bg = jnp.asarray(stop_bigrams, jnp.int32).reshape(-1, 2)
    hit = last_token[:, None] == bg[None, :, 0]          # (B, N)
    b = last_token.shape[0]
    rows = jnp.broadcast_to(jnp.arange(b)[:, None], hit.shape)
    cols = jnp.where(hit, bg[None, :, 1], vocab_size)    # miss → dropped
    return jnp.ones((b, vocab_size), bool).at[rows, cols].set(
        False, mode="drop")


def keep_pred(scaled: Array, top_k: Array, top_p: Array, min_p: Array,
              *, ordered: bool = True) -> Array:
    """Fused top-k ∧ top-p ∧ min-p keep-predicate — THE one definition the
    three individual ``*_pred`` views share, so their equivalence holds by
    construction.

    One softmax and ONE stable descending argsort of the SCALED LOGITS
    serve all three filters: the sort key is the logit (not the prob, whose
    float32 underflow can collapse distinct logits onto equal probs and
    scramble tie order), softmax monotonicity makes the same permutation
    sort the probabilities, and the k-th element of the sorted array is
    exactly the top-k threshold.  The nucleus cutoff accumulates the sorted
    probs in strict element order (``fadda_scan``) and keeps the
    ``whilelt``-shaped prefix  exclusive_mass < top_p  — the exclusive
    prefix is the shifted inclusive scan, never a re-rounded subtraction,
    so the cutoff is bit-identical to the scalar accumulator loop."""
    b, v = scaled.shape
    probs = jax.nn.softmax(scaled, axis=-1)
    order = jnp.argsort(-scaled, axis=-1, stable=True)
    sl = jnp.take_along_axis(scaled, order, axis=-1)
    sp = jnp.take_along_axis(probs, order, axis=-1)
    kth = jnp.take_along_axis(sl, jnp.clip(top_k[:, None] - 1, 0, v - 1),
                              axis=-1)
    keep = (top_k[:, None] <= 0) | (scaled >= kth)
    csum = R.fadda_scan(None, sp) if ordered else jnp.cumsum(sp, axis=-1)
    excl = jnp.concatenate([jnp.zeros_like(csum[..., :1]), csum[..., :-1]],
                           axis=-1)
    # sorted position 0 is retained UNCONDITIONALLY: the kept partition can
    # never go empty, even for degenerate knobs (top_p <= 0, min_p > 1)
    lane = jnp.arange(v, dtype=jnp.int32)[None, :]
    keep_sorted = (excl < top_p[:, None]) | (lane == 0)
    rows = jnp.arange(b)[:, None]
    nucleus = jnp.zeros((b, v), bool).at[rows, order].set(keep_sorted)
    keep &= (top_p[:, None] >= 1.0) | nucleus
    thresh = min_p[:, None] * sp[:, :1]                 # sp[0] == max prob
    minp_keep = (probs >= thresh) | (probs >= sp[:, :1])   # max always kept
    return keep & ((min_p[:, None] <= 0) | minp_keep)


def mask_logits(logits: Array, keep: Array) -> Array:
    """Zeroing predication onto the extended reals: inactive vocab entries
    read as -inf, so softmax/argmax see only the active partition."""
    return jnp.where(keep, logits, NEG_INF)
