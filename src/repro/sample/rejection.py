"""Distribution-preserving rejection sampling for speculative decoding.

Standard speculative rejection (Leviathan et al. / Chen et al.): the draft
proposes x_i ~ q_i; the target accepts with probability min(1, p_i(x_i) /
q_i(x_i)); the first rejected position is re-drawn from the residual
distribution  norm(max(p_i − q_i, 0))  and a fully-accepted window earns a
bonus draw from p_K.  The committed stream is then EXACTLY distributed as
target-alone sampling — speculation stays lossless under stochastic
decoding, the same contract greedy matching gives deterministic decoding.

The acceptance predicate is the FFR partition algebra of ``serve.
speculative``: stochastic accept bits replace the equality predicate, and
``accept_prefix`` (brkb over the first rejection) is unchanged — the first
"faulting" lane is re-executed from the residual, everything after is
discarded.  Greedy lanes keep the exact-match predicate and the target's
own argmax as the fix, so an all-greedy batch commits bit-identically to
the pre-sampling speculative path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import partition as PT
from repro.core import predicate as P

Array = jax.Array

_TINY = 1e-30


def residual_dist(p: Array, q: Array) -> Array:
    """norm(max(p − q, 0)) with a p fallback when the residual has no mass
    (p == q, where a rejection is a measure-zero/rounding event)."""
    r = jnp.maximum(p - q, 0.0)
    mass = jnp.sum(r, axis=-1, keepdims=True)
    return jnp.where(mass > _TINY, r / jnp.maximum(mass, _TINY), p)


def speculative_accept(draft: Array, q_probs: Array, p_probs: Array,
                       tgt_greedy: Array, greedy: Array, round_key: Array):
    """One round of batched rejection acceptance.

    draft      (B, K)      proposed tokens
    q_probs    (B, K, V)   proposal distributions the drafts were drawn from
    p_probs    (B, K+1, V) target distributions (processed like the sampler)
    tgt_greedy (B, K+1)    raw-argmax target tokens (greedy lanes' algebra)
    greedy     (B,)        per-lane greedy predicate
    round_key  (B, 2)      per-lane key for this round (fresh split)

    Returns (acc (B, K) accepted-prefix predicate, fix (B,) the token at the
    first fault — residual draw, bonus draw, or the greedy target token).
    """
    b, k = draft.shape
    pk = p_probs[:, :k]                                   # (B, K, V)
    pd = jnp.take_along_axis(pk, draft[..., None], axis=-1)[..., 0]
    qd = jnp.take_along_axis(q_probs, draft[..., None], axis=-1)[..., 0]
    u = jax.vmap(lambda kk: jax.random.uniform(kk, (k,)))(
        jax.vmap(jax.random.fold_in)(round_key, jnp.zeros((b,), jnp.uint32)))
    stoch_bits = u < jnp.minimum(1.0, pd / jnp.maximum(qd, _TINY))
    match_bits = draft == tgt_greedy[:, :-1]
    acc = PT.accept_prefix(jnp.where(greedy[:, None], match_bits, stoch_bits))
    n_acc = P.cntp(acc)                                   # (B,)

    # residual at the FIRST-FAULT position only (position K's "residual" is
    # the bonus distribution p_K itself, via a zero q row), then draw the
    # fix with a second fold — gathering p/q before residual_dist avoids
    # normalizing K unused (B, V) distributions per round
    q_ext = jnp.concatenate([q_probs, jnp.zeros_like(p_probs[:, :1])], axis=1)
    p_at = jnp.take_along_axis(p_probs, n_acc[:, None, None], axis=1)[:, 0]
    q_at = jnp.take_along_axis(q_ext, n_acc[:, None, None], axis=1)[:, 0]
    res_at = residual_dist(p_at, q_at)                    # (B, V)
    fix_key = jax.vmap(jax.random.fold_in)(round_key, jnp.ones((b,), jnp.uint32))
    g = jax.vmap(lambda kk: jax.random.gumbel(kk, res_at.shape[-1:]))(fix_key)
    stoch_fix = jnp.argmax(
        jnp.where(res_at > 0, jnp.log(jnp.maximum(res_at, _TINY)), -jnp.inf)
        + g, axis=-1).astype(jnp.int32)
    greedy_fix = jnp.take_along_axis(tgt_greedy, n_acc[:, None],
                                     axis=1)[:, 0]
    return acc, jnp.where(greedy, greedy_fix, stoch_fix)
