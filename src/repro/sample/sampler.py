"""The per-lane predicated sampler: one entry point for greedy AND stochastic.

``sample(logits, state)`` runs the whole processor pipeline as predicate
algebra (penalties → temperature → top-k ∧ top-p ∧ min-p ∧ bans → masked
Gumbel-argmax) and then per-lane SELECTS between the stochastic draw and the
bit-exact raw-logits ``argmax`` under the lane's ``greedy`` predicate — a
merging move (§2.3.2), so an all-greedy batch is indistinguishable from the
pre-sampling engine and a mixed batch decodes heterogeneously in one fused
program.  Everything traces into the engine's jitted decode while-loop: no
per-token Python dispatch, no host↔device sync.

PRNG discipline: every call splits every lane's key exactly once (greedy
lanes too — their chain position must stay equal to their token count so a
later stochastic occupant of the lane is unaffected by history).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import processors as PR
from .params import split_keys

Array = jax.Array


def greedy_tokens(logits: Array) -> Array:
    """Bit-exact argmax over raw logits — THE greedy sampler (the single
    copy that ``serve.engine``, ``serve.scheduler`` and ``serve.speculative``
    all route through)."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def process_logits(logits: Array, state: dict,
                   out_tokens: Optional[Array] = None,
                   n_out: Optional[Array] = None,
                   ban: Optional[Array] = None) -> Array:
    """The processor pipeline: penalised, temperature-scaled logits with the
    inactive vocab partition at -inf.  ``softmax`` of the result is the
    lane's categorical distribution; Gumbel-argmax of it is a draw."""
    if out_tokens is not None:
        logits = PR.apply_penalties(logits, out_tokens, n_out,
                                    state["repetition_penalty"],
                                    state["presence_penalty"])
    scaled = PR.temperature_scale(logits, state["temperature"])
    # the ban predicate applies BEFORE top-k/top-p/min-p generation: banned
    # entries read -inf, so they carry zero nucleus mass, can't set the
    # top-k threshold, and the kept set always contains the (allowed)
    # argmax — the partition can never go empty
    if ban is not None:
        scaled = PR.mask_logits(scaled, ban[None, :])
    keep = PR.keep_pred(scaled, state["top_k"], state["top_p"],
                        state["min_p"])
    return PR.mask_logits(scaled, keep)


def categorical_probs(logits: Array, state: dict,
                      out_tokens: Optional[Array] = None,
                      n_out: Optional[Array] = None,
                      ban: Optional[Array] = None) -> Array:
    """Normalized per-lane sampling distribution (B, V) — what speculative
    rejection sampling verifies against."""
    return jax.nn.softmax(
        process_logits(logits, state, out_tokens, n_out, ban), axis=-1)


def gumbel_argmax(masked_logits: Array, subkeys: Array) -> Array:
    """Draw one token per lane from softmax(masked_logits) via per-lane
    Gumbel noise: argmax(logits + g) ~ Categorical(softmax(logits)).
    Inactive (-inf) vocab entries can never win."""
    g = jax.vmap(lambda k: jax.random.gumbel(k, masked_logits.shape[-1:]))(
        subkeys)
    return jnp.argmax(masked_logits + g, axis=-1).astype(jnp.int32)


def sample(logits: Array, state: dict,
           out_tokens: Optional[Array] = None,
           n_out: Optional[Array] = None,
           ban: Optional[Array] = None):
    """Per-lane heterogeneous sampling: (tokens (B,), new_state).

    Greedy lanes return the bit-exact raw-logits argmax (modulo ``ban``,
    which also constrains greedy decoding when set); stochastic lanes draw
    from their processed distribution with their own key.  jit-safe;
    designed to live inside the decode while-loop body.
    """
    state, sub = split_keys(state)
    raw = logits if ban is None else PR.mask_logits(logits, ban[None, :])
    arg = greedy_tokens(raw)
    masked = process_logits(logits, state, out_tokens, n_out, ban)
    stoch = gumbel_argmax(masked, sub)
    return jnp.where(state["greedy"], arg, stoch), state
