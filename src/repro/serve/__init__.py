from .engine import ServeEngine  # noqa: F401
from .scheduler import ContinuousBatchingScheduler, Request  # noqa: F401
from .speculative import speculative_decode  # noqa: F401
