from repro.sample import SamplingParams  # noqa: F401  (re-export: serve API)

from .chaos import ChaosConfig, ChaosMonkey, burst_trace  # noqa: F401
from .engine import ServeEngine  # noqa: F401
from .scheduler import (  # noqa: F401
    ContinuousBatchingScheduler,
    FinishReason,
    HostSwapStore,
    PageAllocator,
    PrefixIndex,
    PreemptedState,
    Request,
    RequestRejected,
)
from .speculative import speculative_decode  # noqa: F401
