from repro.sample import SamplingParams  # noqa: F401  (re-export: serve API)

from .engine import ServeEngine  # noqa: F401
from .scheduler import (  # noqa: F401
    ContinuousBatchingScheduler,
    HostSwapStore,
    PageAllocator,
    PrefixIndex,
    Request,
)
from .speculative import speculative_decode  # noqa: F401
