"""Deterministic fault injection for the serving stack (the chaos harness).

The paper's first-faulting loads (§2.5.2) turn a mid-vector fault into
partial progress plus resume instead of failure; this module is the traffic
analogue — inject the faults a production serving system actually sees and
assert the scheduler degrades the same way: partial progress, bit-exact
state, never a leak and never wrong tokens.

Everything is driven by ONE seeded ``numpy.random.RandomState``, so a chaos
schedule is a pure function of ``ChaosConfig.seed`` — a failing soak run
replays exactly from its config.  Three injection points:

* ``PageAllocator.alloc`` fails on schedule (returns None as if the pool
  were exhausted) — exercises admission back-off, ``page_waits`` and the
  preemption/resume retry path.
* ``HostSwapStore.put`` flips one byte in the stored entry AFTER its CRC was
  taken — the next ``get`` must detect the mismatch, drop the entry and
  degrade that request to a cold prefill (``swap_checksum_failures``),
  never serve corrupt K/V.
* ``on_round`` cancels random live requests between scheduler rounds —
  exercises every branch of ``cancel`` (queued / preempted / pending /
  resident).

``ChaosMonkey.run`` drives a scheduler to drain with per-round injection;
``burst_trace`` builds clustered-arrival overload traces.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """One deterministic fault schedule.  Rates are per-opportunity
    probabilities (per ``alloc`` call / per ``put`` / per live request per
    round); ``burst_arrivals`` is the cluster size ``burst_trace`` emits at
    each arrival instant (0 = smooth one-at-a-time arrivals)."""
    seed: int = 0
    alloc_fail_rate: float = 0.0
    swap_corrupt_rate: float = 0.0
    cancel_rate: float = 0.0
    burst_arrivals: int = 0


class ChaosMonkey:
    """Installable fault injector around one scheduler.

    ``install`` wraps the scheduler's allocator / swap store in place (the
    wrappers call through to the originals, so allocator invariants keep
    holding — a chaotic failure is indistinguishable from a genuinely full
    pool).  Injection counts land on the instance (``alloc_failures``,
    ``corruptions``, ``cancels``) so a soak test can assert the schedule
    actually fired.
    """

    def __init__(self, config: ChaosConfig):
        self.config = config
        self.rng = np.random.RandomState(config.seed)
        self.alloc_failures = 0
        self.corruptions = 0
        self.cancels = 0

    def install(self, sched) -> "ChaosMonkey":
        cfg = self.config
        if cfg.alloc_fail_rate > 0 and getattr(sched, "page_size", None) \
                is not None:
            allocator = sched.allocator
            inner_alloc = allocator.alloc

            def chaotic_alloc(n: int):
                if n > 0 and self.rng.random_sample() < cfg.alloc_fail_rate:
                    self.alloc_failures += 1
                    return None
                return inner_alloc(n)

            allocator.alloc = chaotic_alloc
        if cfg.swap_corrupt_rate > 0 and getattr(sched, "host_swap",
                                                 None) is not None:
            store = sched.host_swap
            inner_put = store.put

            def chaotic_put(key: bytes, entry: dict):
                fresh = key not in store
                inner_put(key, entry)
                # corrupt AFTER the CRC was taken, and only entries this put
                # actually inserted — the flip models host memory rotting
                # under the store, which the next get must catch
                if fresh and key in store._store \
                        and self.rng.random_sample() < cfg.swap_corrupt_rate:
                    ent = store._store[key]
                    pk = sorted(ent)[self.rng.randint(len(ent))]
                    # numpy views of device arrays are read-only: corrupt an
                    # owned copy and swap it into the entry
                    b = np.array(ent[pk])
                    flat = b.view(np.uint8).reshape(-1)
                    flat[self.rng.randint(flat.size)] ^= 0xFF
                    ent[pk] = b
                    self.corruptions += 1

            store.put = chaotic_put
        return self

    def on_round(self, sched):
        """Between-round injection: cancel each live request with
        probability ``cancel_rate`` (deterministic in submission order)."""
        if self.config.cancel_rate <= 0:
            return
        for rid in sorted(sched._live_req):
            if self.rng.random_sample() < self.config.cancel_rate:
                if sched.cancel(rid):
                    self.cancels += 1

    def run(self, sched) -> dict:
        """Drive ``sched`` to drain with per-round injection; returns its
        results dict (same contract as ``scheduler.run``)."""
        while (sched.queue or sched._preempted
               or (sched.lane_rid >= 0).any()):
            sched.step()
            self.on_round(sched)
        sched._flush_stash()
        return sched.results


def burst_trace(n_requests: int, *, prompt_len: int, vocab: int,
                burst: int = 0, gap: float = 4.0, seed: int = 0,
                priority_of=None) -> list:
    """Clustered-arrival overload trace: ``n_requests`` random prompts
    arriving ``burst`` at a time (every ``gap`` decode steps); ``burst=0``
    spaces them one per instant.  Returns ``[{"tokens", "arrival",
    "priority"}, ...]`` ready to feed ``submit``; ``priority_of(i)`` maps
    request index to priority (default all 0)."""
    rng = np.random.RandomState(seed)
    group = burst if burst > 0 else 1
    reqs = []
    for i in range(n_requests):
        reqs.append({
            "tokens": rng.randint(1, vocab, size=(prompt_len,)).astype(
                np.int32),
            "arrival": float((i // group) * gap),
            "priority": int(priority_of(i)) if priority_of else 0,
        })
    return reqs
