"""Batched serving engine with a fully-jitted vector-partitioned decode loop.

A batch of requests is a VECTOR (paper §2.3.4): each lane is one request.
Prefill uses ragged whilelt lengths; the decode loop is ONE jitted XLA while
loop over a shrinking active partition (§2.3.4) — per-lane stop conditions
retire lanes inside the compiled loop, so there is no per-token Python
dispatch and no cache rewriting: the model's own ``dynamic_update_slice``
writes are the only cache mutation (XLA aliases them in place).

Inactive lanes keep decoding architecturally but their effects are not
observed: sampled tokens are merging-predicated to the stop token, output
slots are write-masked, and their cache slots become garbage-beyond-pos —
harmless, because a finished lane is always refilled through
``repro.models.slot_update`` (a fresh prefill) before it is reused.  That is
the contract that makes continuous batching (see ``serve.scheduler``) a pure
lane-permutation problem.

Sampling is per-lane predicated (``repro.sample``): every lane carries its
own SamplingParams row (temperature/top-k/top-p/min-p/penalties/seed/greedy
flag) and PRNG key inside the decode carry, so heterogeneous stochastic
decoding runs in the SAME jitted while-loop — greedy lanes select the
bit-exact raw argmax under a merging predicate, and a request's stream is a
function of (seed, prompt, params) only, never of batch composition.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro import sample as S
from repro.core import paging as PG
from repro.core import predicate as P
from repro.dist import serve as DS
from repro.dist import sharding as SH
from repro.models import (gather_lanes, get_model, is_paged, merge_lanes,
                          paged_decode_ok, paged_view, paged_writeback,
                          slot_update, to_paged)
from repro.obs import Obs
from repro.sample.processors import ban_pred, mask_logits


@dataclasses.dataclass
class ServeEngine:
    """Family-agnostic generation engine: jitted prefill / decode-burst /
    fused-serve-step programs over one model config + params.

    The engine owns everything that touches the device — cache allocation
    (dense, paged, or quantized paged via ``page_dtype``), the decode burst
    with per-lane predication, the fused one-dispatch serve step, and the
    mesh-sharded variants — while ``ContinuousBatchingScheduler`` owns all
    host-side traffic state (lanes, pages, prefixes, the swap tier).  Entry
    points: ``generate`` (static batch), ``make_paged_cache`` /
    ``_fused_step`` and friends (driven by the scheduler).  All jitted
    programs are shape-bucketed so ragged traffic compiles a bounded set of
    executables; see docs/ARCHITECTURE.md for the round anatomy.
    """

    cfg: object
    params: object
    max_new_tokens: int = 32
    stop_token: int = 0
    # engine-wide default sampling spec for requests/batches that don't carry
    # their own (None = greedy argmax, the bit-exact legacy behavior)
    default_sampling: Optional[S.SamplingParams] = None
    # constrained decoding: token ids masked out of EVERY lane's vocab
    # partition (greedy lanes included) before sampling
    banned_tokens: Optional[Sequence[int]] = None
    # paged decode: "native" (the default; "kernel" is a legacy alias) reads
    # K/V directly through the page table inside flash attention and
    # scatter-stores each new token into the lane's tail page — no dense-view
    # materialization on the decode hot path.  "gather" is the reference
    # oracle: materialize the dense view through the table, run the unchanged
    # family decode, scatter the one new token back (bitwise identical to the
    # dense cache BY CONSTRUCTION; tests pin the native path against it).
    paged_attn: str = "native"
    # quantized KV pages: None (full precision) or "int8" / "fp8" — pools
    # store narrow elements with per-slot absmax scale pools riding alongside
    # (``<key>_pages_scale``); flash attention widens them in the gather
    # (SVE §2.3.3 extending loads).  Applies to every paged cache this engine
    # allocates (make_paged_cache / generate(page_size=...)).
    page_dtype: Optional[str] = None
    # mesh-sharded serving: a jax Mesh with "model" (TP) and/or "data" (lane)
    # axes.  Params commit to their TP placement, every jitted entry point
    # traces under SERVE_RULES so the model's activation constraints resolve,
    # and the scheduler commits its serve state through ``dist.serve`` —
    # model code itself never sees the mesh (the VL-agnostic contract).
    mesh: Optional[object] = None
    # observability handle (repro.obs.Obs): one-shot ``generate`` records its
    # prefill/decode seams here.  The scheduler does NOT inherit this — it
    # defaults to its own registry; pass the same handle to both when one
    # combined timeline is wanted (launch --trace-out does).
    obs: Optional[object] = None

    def __post_init__(self):
        if self.obs is None:
            self.obs = Obs()
        if self.paged_attn not in ("native", "kernel", "gather"):
            raise ValueError(
                f"paged_attn must be 'native' ('kernel' alias) or 'gather', "
                f"got {self.paged_attn!r}")
        if self.mesh is not None and getattr(self.cfg, "act_shard",
                                             "none") == "none":
            # activation constraints are what steer GSPMD away from
            # all-gathering pools/heads; enable them unless the caller
            # pinned a specific mode
            self.cfg = dataclasses.replace(self.cfg, act_shard="tp")
        self.model = get_model(self.cfg)
        # logits run over the PADDED vocab (the model already predicates the
        # pad lanes to -1e30, so leaving them "allowed" here is inert)
        v = getattr(self.cfg, "padded_vocab", self.cfg.vocab_size)
        self._ban = (ban_pred(v, tuple(self.banned_tokens))
                     if self.banned_tokens else None)
        self._prefill = jax.jit(
            lambda p, b, c: self.model.prefill(p, self.cfg, b, c))
        # donate the mutable decode state (cache/out_buf/tok/p/n_gen and the
        # sampler lane state) so XLA updates it in place instead of copying
        # the KV cache every burst; the CPU backend has no donation (it
        # would only warn), so gate it
        donate = (1, 2, 3, 4, 5, 7) if jax.default_backend() != "cpu" else ()
        self._decode_chunk = jax.jit(self._decode_chunk_impl,
                                     static_argnames=("n_steps", "stochastic",
                                                      "width"),
                                     donate_argnums=donate)
        # serve-mode variants for the scheduler's async host loop: out_buf /
        # p / n_gen must NOT be donated (the overlap harvest still holds the
        # previous round's handles to them), so only the cache, the sampler
        # state and (for the fused program) the per-round inputs go in place
        serve_donate = (1, 7) if jax.default_backend() != "cpu" else ()
        self._decode_chunk_serve = jax.jit(
            self._decode_chunk_impl,
            static_argnames=("n_steps", "stochastic", "width"),
            donate_argnums=serve_donate)
        fused_donate = ((1, 6, 7, 8, 9)
                        if jax.default_backend() != "cpu" else ())
        self._fused_step = jax.jit(
            self._fused_step_impl,
            static_argnames=("n_steps", "stochastic", "admit_stoch",
                            "part_final", "part_stoch", "max_len", "width"),
            donate_argnums=fused_donate)
        if self.page_dtype is not None:
            PG.resolve_page_dtype(self.page_dtype)   # validate eagerly
        # host-swap page movers: batched whole-page reads/writes used by the
        # scheduler's eviction tier (device -> host spill, host -> device
        # page-in).  Eager jitted calls outside the fused program; the
        # scheduler pads the page-id vectors to pow2 buckets.
        self._gather_blocks = jax.jit(self._gather_blocks_impl)
        self._scatter_blocks = jax.jit(self._scatter_blocks_impl)
        # preemption lane movers: one-dispatch gather of a lane's dense
        # carries + decode rows (spill half) and the splice that puts them
        # back (resume half) — the scheduler's bit-exact preempt/resume path
        self._spill_lane = jax.jit(self._spill_lane_impl)
        self._resume_lane = jax.jit(self._resume_lane_impl)
        if self.mesh is not None:
            # commit params to their TP placement and trace every entry
            # point under the ambient serve rules so the model's logical-
            # axis constraints resolve against THIS mesh
            self.params = DS.shard_params(self.model, self.cfg, self.params,
                                          self.mesh)
            for name in ("_prefill", "_decode_chunk", "_decode_chunk_serve",
                         "_fused_step", "_gather_blocks", "_scatter_blocks",
                         "_spill_lane", "_resume_lane"):
                setattr(self, name, self._with_mesh(getattr(self, name)))
        self._warned_gather_fallback = False

    def _with_mesh(self, fn):
        def run(*args, **kwargs):
            with SH.use_mesh_rules(self.mesh, SH.SERVE_RULES):
                return fn(*args, **kwargs)

        def lower(*args, **kwargs):
            # introspection path (HLO collective audits): same ambient rules
            with SH.use_mesh_rules(self.mesh, SH.SERVE_RULES):
                return fn.lower(*args, **kwargs)
        run.lower = lower
        return run

    def _sample(self, logits, sstate=None, out_buf=None, n_gen=None):
        """Sample one token per lane through ``repro.sample`` (the single
        sampler entry point).  With no state: bit-exact greedy argmax."""
        # gather the (tiny) logit row off the vocab-sharded unembed output:
        # the sampler's ordered scans (sort, FADDA cumsum, Gumbel) must run
        # on a whole vocab row or their FP association order — and thus the
        # sampled token — would differ from the 1-device engine
        logits = SH.constrain(logits, ("batch",) + (None,) * (logits.ndim - 1))
        if sstate is None:
            return S.greedy_tokens(logits if self._ban is None else
                                   mask_logits(logits, self._ban[None, :]))
        return S.sample(logits, sstate, out_tokens=out_buf, n_out=n_gen,
                        ban=self._ban)

    def make_state(self, b: int, sampling=None) -> dict:
        """Batched sampler lane state for ``b`` lanes (falls back to the
        engine's ``default_sampling``, then to greedy)."""
        if isinstance(sampling, dict):
            return sampling
        return S.lane_state(self.default_sampling if sampling is None
                            else sampling, b)

    # ------------------------------------------------------------------
    # jitted decode loop
    # ------------------------------------------------------------------

    def _decode_chunk_impl(self, params, cache, out_buf, tok, p, n_gen,
                           lane_budget, sstate, *, n_steps: int,
                           stochastic: bool = True,
                           width: Optional[int] = None):
        """The decode hot loop as ONE XLA while: §2.3.4 dynamic exits.

        Every iteration decodes all lanes, but only the active partition
        commits tokens; a lane leaves the partition when it emits the stop
        token or its per-lane budget runs out.  ``n_steps`` caps the burst so
        the continuous-batching scheduler can admit queued requests between
        calls; ``generate`` passes n_steps = max_new_tokens and uniform
        budgets so the same loop serves both paths (bit-identity between the
        one-shot and scheduled engines follows by construction).

        ``sstate`` is the per-lane sampler state (``repro.sample``): keys
        split once per iteration for EVERY lane — a live lane's chain
        position therefore equals its committed token count, independent of
        chunk boundaries and co-scheduled traffic — and the whole processor
        pipeline (penalty gathers over the lane's own out_buf, top-k/top-p
        predicates, the ordered top-p cumsum) traces into this while-loop:
        no per-token host dispatch.  ``stochastic=False`` (a static flag the
        caller derives host-side: no live lane samples) compiles the legacy
        argmax-only body — greedy traffic pays zero pipeline cost and the
        sampler state passes through untouched, which is sound because a
        stochastic lane's key chain only needs to advance on steps it is
        live for, and every such step runs a stochastic=True chunk.
        Returns (cache, out_buf, tok, p, n_gen, sstate, steps_run).
        """
        return self._burst(params, cache, out_buf, tok, p, n_gen,
                           lane_budget, sstate, n_steps=n_steps,
                           stochastic=stochastic, width=width)

    def _burst(self, params, cache, out_buf, tok, p, n_gen, lane_budget,
               sstate, *, n_steps: int, stochastic: bool,
               width: Optional[int]):
        """Run the decode burst, optionally NARROWED to the first ``width``
        lanes (a static pow2 bucket the scheduler derives from its host-side
        occupancy view: compaction keeps live lanes at the low indices, so
        the burst executes at the smallest bucket covering them — SVE
        predicate-narrowing applied to the batch axis).  Lanes at or above
        ``width`` are guaranteed inactive (p False) for the whole burst and
        pass through untouched, so per-lane results are bit-identical to the
        full-width burst — the scheduler only narrows families whose decode
        is lane-independent (``lane_independent_decode``).  jit-safe."""
        if width is None or width >= out_buf.shape[0]:
            return self._decode_loop(params, cache, out_buf, tok, p, n_gen,
                                     lane_budget, sstate, n_steps=n_steps,
                                     stochastic=stochastic)
        w = jnp.arange(width, dtype=jnp.int32)
        sub_cache = gather_lanes(self.cfg, cache, w)
        sub_state = S.gather_lanes(sstate, w)
        (sub_cache, sub_out, sub_tok, sub_p, sub_ngen, sub_state,
         steps) = self._decode_loop(
            params, sub_cache, out_buf[:width], tok[:width], p[:width],
            n_gen[:width], lane_budget[:width], sub_state,
            n_steps=n_steps, stochastic=stochastic)
        # merge_lanes (not slot_update): a narrowed PAGED burst scatter-
        # stored its tokens into the shared pools riding sub_cache
        cache = merge_lanes(self.cfg, cache, w, sub_cache)
        sstate = S.slot_update(sstate, w, sub_state)
        out_buf = out_buf.at[:width].set(sub_out)
        tok = tok.at[:width].set(sub_tok)
        p = p.at[:width].set(sub_p)
        n_gen = n_gen.at[:width].set(sub_ngen)
        return cache, out_buf, tok, p, n_gen, sstate, steps

    def _decode_loop(self, params, cache, out_buf, tok, p, n_gen,
                     lane_budget, sstate, *, n_steps: int, stochastic: bool):
        """The while-loop body shared by ``_decode_chunk`` and the fused
        serve step (identical trace, so the two compile the same loop)."""
        stop = self.stop_token
        b, max_out = out_buf.shape
        rows = jnp.arange(b)

        def loop_cond(carry):
            _, _, _, p, _, _, step = carry
            return jnp.any(p) & (step < n_steps)

        def loop_body(carry):
            cache, out_buf, tok, p, n_gen, sstate, step = carry
            logits, cache = self._cached_decode(params, {"token": tok[:, None]},
                                                cache)
            if stochastic:
                nxt, sstate = self._sample(logits, sstate, out_buf, n_gen)
            else:
                nxt = self._sample(logits)
            nxt = P.merging(p, nxt, jnp.full_like(nxt, stop))
            col = jnp.clip(n_gen, 0, max_out - 1)
            out_buf = out_buf.at[rows, col].set(
                jnp.where(p, nxt, out_buf[rows, col]))
            n_gen = n_gen + p.astype(jnp.int32)
            p = p & (nxt != stop) & (n_gen < lane_budget)
            return cache, out_buf, nxt, p, n_gen, sstate, step + 1

        cache, out_buf, tok, p, n_gen, sstate, steps = jax.lax.while_loop(
            loop_cond, loop_body,
            (cache, out_buf, tok, p, n_gen, sstate, jnp.int32(0)))
        return cache, out_buf, tok, p, n_gen, sstate, steps

    def _cached_decode(self, params, batch, cache):
        """One decode step against a dense OR paged cache.

        Paged "native" (default; "kernel" accepted as a legacy alias): the
        family's decode reads K/V through the page table inside flash
        attention and scatter-stores its new token straight into the lane's
        tail page — no dense-view materialization on the hot path.  Paged
        "gather" (the reference oracle): gather-load the dense view through
        the table, run the family's unchanged decode, scatter-store the new
        token back — bitwise equal to the dense engine because the view IS
        the dense cache.  All of it traces into the jitted decode loop.
        """
        if not is_paged(cache):
            return self.model.decode(params, self.cfg, batch, cache)
        if self.paged_attn != "gather":
            if paged_decode_ok(self.cfg):
                return self.model.decode(params, self.cfg, batch, cache)
            if not self._warned_gather_fallback:
                # trace-time emission: fires once per engine, not per step
                warnings.warn(
                    f"family '{self.cfg.family}' has no native paged decode; "
                    "falling back to the gather bridge (dense view "
                    "materialized through the page table every step)",
                    RuntimeWarning, stacklevel=2)
                self._warned_gather_fallback = True
        view = paged_view(self.cfg, cache)
        pos = view["pos"]
        logits, view = self.model.decode(params, self.cfg, batch, view)
        return logits, paged_writeback(self.cfg, cache, view, pos)

    # ------------------------------------------------------------------
    # fused serve step: prefill chunk(s) + admission + decode burst in ONE
    # dispatch (the scalar-loop-tail elimination applied to the host loop)
    # ------------------------------------------------------------------

    def _seed_pages(self, cache, sub_cache, seed_tab, seed_len, max_len: int):
        """Gather resident shared-prefix pages of the live paged ``cache``
        into a dense prefill ``sub_cache`` (positions [0, seed_len) per row),
        so suffix rows attend over the donor's K/V.  jit-safe."""
        spec = self.model.paged_cache_spec(self.cfg)
        m = seed_tab.shape[0]
        mask = jnp.arange(max_len, dtype=jnp.int32)[None, :] < seed_len[:, None]
        sub_cache = dict(sub_cache)
        for key, lead in spec.items():
            # extending gather: a quantized cache's seed widens through the
            # scale pool, so the dense prefill sub-cache is full precision
            view = PG.gather_pages(cache[key + "_pages"], seed_tab,
                                   n_lead=len(lead),
                                   scale=cache.get(key + "_pages_scale"))
            mm = mask.reshape((1,) * len(lead) + (m, 1, max_len, 1))
            sub_cache[key] = jnp.where(mm, view.astype(sub_cache[key].dtype),
                                       sub_cache[key])
        return sub_cache

    def _install_pages(self, cache, sub_cache, rows, cols, dsts, tab_rows,
                       lanes):
        """Scatter freshly prefilled K/V blocks ``(rows, cols)`` of the dense
        ``sub_cache`` into physical pages ``dsts`` of the live paged
        ``cache`` and install the page-table rows at ``lanes``.  Padding
        entries aim at the trash page / out-of-range lanes, which JAX
        scatters drop.  jit-safe."""
        spec = self.model.paged_cache_spec(self.cfg)
        cache = dict(cache)
        n_pages = cache["page_table"].shape[1]
        for key, lead in spec.items():
            dn = sub_cache[key]                     # lead+(m,Hkv,S,Dh)
            nl = len(lead)
            shp = dn.shape
            ps = shp[-2] // n_pages
            dnp = dn.reshape(shp[:nl + 2] + (n_pages, ps, shp[-1]))
            dnp = jnp.moveaxis(dnp, nl, 0)          # (m,)+lead+(Hkv,n,ps,D)
            dnp = jnp.moveaxis(dnp, nl + 2, 1)      # (m,n_pages)+lead+...
            blocks = dnp[rows, cols]                # (K,)+lead+(Hkv,ps,D)
            sc = cache.get(key + "_pages_scale")
            if sc is not None:                      # truncating store
                (cache[key + "_pages"],
                 cache[key + "_pages_scale"]) = PG.scatter_block_q(
                    cache[key + "_pages"], sc, dsts, blocks, n_lead=nl)
            else:
                cache[key + "_pages"] = PG.scatter_block(
                    cache[key + "_pages"], dsts, blocks, n_lead=nl)
        cache["page_table"] = cache["page_table"].at[lanes].set(tab_rows)
        return cache

    # ------------------------------------------------------------------
    # host-swap page movers (the scheduler's eviction tier)
    # ------------------------------------------------------------------

    def _gather_blocks_impl(self, cache, pids):
        """Batched whole-page read: for each pool (and scale pool) of the
        paged ``cache``, gather pages ``pids (K,)`` as ``(K,) + lead +
        (Hkv, ps[, D])`` blocks — the device->host half of a spill.  A
        quantized cache spills its NARROW bytes plus scales, so a later
        page-in restores the pool rows bit-exactly."""
        spec = self.model.paged_cache_spec(self.cfg)
        out = {}
        for key, lead in spec.items():
            for suffix in ("_pages", "_pages_scale"):
                pk = key + suffix
                if pk in cache:
                    out[pk] = PG.gather_block(cache[pk], pids,
                                              n_lead=len(lead))
        return out

    def _scatter_blocks_impl(self, cache, pids, blocks):
        """Batched whole-page write: scatter host-held ``blocks`` (the dict
        ``_gather_blocks`` produced) into pages ``pids`` — the page-in half
        of a swap.  Padding entries aim at the trash page."""
        spec = self.model.paged_cache_spec(self.cfg)
        cache = dict(cache)
        for key, lead in spec.items():
            for suffix in ("_pages", "_pages_scale"):
                pk = key + suffix
                if pk in cache:
                    cache[pk] = PG.scatter_block(cache[pk], pids, blocks[pk],
                                                 n_lead=len(lead))
        return cache

    # ------------------------------------------------------------------
    # preemption lane movers (the scheduler's bit-exact preempt/resume)
    # ------------------------------------------------------------------

    def _spill_lane_impl(self, cache, out_buf, tok, n_gen, budget, sstate,
                         lane):
        """Gather ONE lane's host-spillable state in a single dispatch: its
        dense per-lane cache carries (every key with a declared lane axis —
        page pools and the page table are excluded; their content moves
        through ``_gather_blocks``), its decode rows (out_buf/tok/n_gen/
        budget) and its sampler-state row.  Together with the lane's page
        blocks this is the complete request state: splicing it back resumes
        the token stream byte-exactly (the per-lane PRNG chain position is
        the committed token count, which rides ``n_gen``)."""
        lane = jnp.asarray(lane, jnp.int32)
        axes = self.model.cache_batch_axes(self.cfg)
        lc = gather_lanes(self.cfg, cache, lane)
        dense = {k: v for k, v in lc.items() if k in axes}
        row = {"out": out_buf[lane], "tok": tok[lane],
               "ngen": n_gen[lane], "budget": budget[lane]}
        return dense, row, S.gather_lanes(sstate, lane)

    def _resume_lane_impl(self, cache, out_buf, tok, p, n_gen, budget, sstate,
                          lane, dense, row, srow, table_row):
        """Splice a spilled lane back (the resume half of preemption): the
        dense carries slot_update into the lane (pool keys absent from
        ``dense`` pass through untouched), the rebuilt page-table row is
        installed when paged, and the decode/sampler rows are restored
        exactly as spilled — the lane continues as if never interrupted."""
        lane = jnp.asarray(lane, jnp.int32)
        cache = slot_update(self.cfg, cache, lane, dense)
        if table_row is not None:
            cache = dict(cache)
            cache["page_table"] = cache["page_table"].at[lane].set(table_row)
        sstate = S.slot_update(sstate, lane, srow)
        out_buf = out_buf.at[lane].set(row["out"])
        tok = tok.at[lane].set(row["tok"])
        n_gen = n_gen.at[lane].set(row["ngen"])
        budget = budget.at[lane].set(row["budget"])
        p = p.at[lane].set(True)
        return cache, out_buf, tok, p, n_gen, budget, sstate

    def _splice_admission(self, cache, out_buf, tok, p, n_gen, budget, sstate,
                          lanes, first_tok, sub_cache, sub_state, budgets,
                          info):
        """Replay the scheduler's admission tail inside the fused trace:
        page installs, cache/sampler slot_update, and the per-lane decode
        seeds.  ``lanes`` may carry out-of-range entries for padded rows —
        every ``.at[]`` scatter drops them, which is how dummy-row trimming
        happens without a host round-trip."""
        if "copy_dsts" in info:
            cache = self._install_pages(cache, sub_cache, info["copy_rows"],
                                        info["copy_cols"], info["copy_dsts"],
                                        info["tab_rows"], lanes)
        cache = slot_update(self.cfg, cache, lanes, sub_cache)
        sstate = S.slot_update(sstate, lanes, sub_state)
        tok = tok.at[lanes].set(first_tok)
        out_buf = out_buf.at[lanes].set(0)
        out_buf = out_buf.at[lanes, 0].set(first_tok)
        n_gen = n_gen.at[lanes].set(1)
        budget = budget.at[lanes].set(budgets)
        alive = (first_tok != self.stop_token) & (budgets > 1)
        p = p.at[lanes].set(alive)
        return cache, out_buf, tok, p, n_gen, budget, sstate

    def _fused_step_impl(self, params, cache, out_buf, tok, p, n_gen, budget,
                         sstate, admit, parts, *, n_steps: int,
                         stochastic: bool, admit_stoch: bool,
                         part_final: tuple, part_stoch: tuple, max_len: int,
                         width: Optional[int] = None):
        """ONE dispatch for a whole scheduling round: the round's chunked-
        prefill chunk(s), the admission sub-batch (zero-init -> prefix seed
        -> prefill -> first-token sample -> page install -> lane splice), and
        an ``n_steps`` decode burst — the same ops the legacy host loop
        issued as separate dispatches, in the same order, now fused so the
        host touches the device once per round.

        ``admit`` is None or a dict of device arrays assembled host-side
        (batch / lanes / budgets / sampler rows / page-copy plan); ``parts``
        is a tuple of per-partial dicts (batch + accumulating sub-cache,
        plus splice data when the chunk is final).  ``part_final`` /
        ``part_stoch`` are static per-partial flags.  Returns
        (cache, out_buf, tok, p, n_gen, budget, sstate, steps_run,
        new_caches-of-non-final-partials).
        """
        new_part_caches = []
        for i, part in enumerate(parts):
            sub_in = part["cache"]
            if "seed_tab" in part:
                # first chunk of a prefix-shared partial: the donor's page
                # install has executed by this point in the trace
                sub_in = self._seed_pages(cache, sub_in, part["seed_tab"],
                                          part["seed_len"], max_len)
            logits, sub = self.model.prefill(params, self.cfg, part["batch"],
                                             sub_in)
            if not part_final[i]:
                new_part_caches.append(sub)
                continue
            if part_stoch[i]:
                first, sub_state = self._sample(logits, part["sub_state"])
            else:
                first = self._sample(logits)
                sub_state = part["sub_state"]
            (cache, out_buf, tok, p, n_gen, budget,
             sstate) = self._splice_admission(
                cache, out_buf, tok, p, n_gen, budget, sstate, part["lane"],
                first, sub, sub_state, part["budget"], part)
        if admit is not None:
            batch = admit["batch"]
            m = batch["tokens"].shape[0]
            # fresh zeros inside the trace: pin their serve placement so
            # GSPMD doesn't materialise them replicated (identity unsharded)
            sub_cache = DS.constrain_cache(self.cfg,
                                           self.make_cache(m, max_len, batch))
            if "seed_tab" in admit:
                sub_cache = self._seed_pages(cache, sub_cache,
                                             admit["seed_tab"],
                                             admit["seed_len"], max_len)
            logits, sub_cache = self.model.prefill(params, self.cfg, batch,
                                                   sub_cache)
            if admit_stoch:
                first, sub_state = self._sample(logits, admit["sub_state"])
            else:
                first = self._sample(logits)
                sub_state = admit["sub_state"]
            (cache, out_buf, tok, p, n_gen, budget,
             sstate) = self._splice_admission(
                cache, out_buf, tok, p, n_gen, budget, sstate,
                admit["lanes"], first, sub_cache, sub_state,
                admit["budgets"], admit)
        cache, out_buf, tok, p, n_gen, sstate, steps = self._burst(
            params, cache, out_buf, tok, p, n_gen, budget, sstate,
            n_steps=n_steps, stochastic=stochastic, width=width)
        return (cache, out_buf, tok, p, n_gen, budget, sstate, steps,
                tuple(new_part_caches))

    # ------------------------------------------------------------------
    # one-shot batch API
    # ------------------------------------------------------------------

    def make_paged_cache(self, b: int, max_len: int, *, page_size: int,
                         pool_pages: int, batch: Optional[dict] = None,
                         src_len: Optional[int] = None):
        """Allocate a paged cache: shared page pools + per-lane page table
        (narrow pools + scale pools when the engine has a ``page_dtype``)."""
        if self.cfg.family == "encdec":
            sl = src_len if src_len is not None else batch["src_emb"].shape[1]
            return self.model.make_paged_cache(
                self.cfg, b, max_len, src_len=sl,
                page_size=page_size, pool_pages=pool_pages,
                page_dtype=self.page_dtype)
        return self.model.make_paged_cache(self.cfg, b, max_len,
                                           page_size=page_size,
                                           pool_pages=pool_pages,
                                           page_dtype=self.page_dtype)

    def make_cache(self, b: int, max_len: int, batch: Optional[dict] = None,
                   src_len: Optional[int] = None):
        """Allocate a cache for ``b`` request lanes (family-dispatched).
        encdec sizes its cross-attention memory from ``batch["src_emb"]`` or
        an explicit ``src_len`` (the scheduler's batch-free allocations)."""
        if self.cfg.family == "encdec":
            sl = src_len if src_len is not None else batch["src_emb"].shape[1]
            return self.model.make_cache(self.cfg, b, max_len, src_len=sl)
        if self.cfg.family == "ssm":
            return self.model.make_cache(self.cfg, b)
        return self.model.make_cache(self.cfg, b, max_len)

    def generate(self, batch, *, max_len: Optional[int] = None,
                 sampling=None, page_size: Optional[int] = None,
                 pool_pages: Optional[int] = None):
        """batch: {"tokens": (B, S) prompts, "lens": (B,)} (+ modality extras).

        ``sampling`` is None (engine default / greedy), one ``SamplingParams``
        broadcast over lanes, a per-lane sequence of them, or a pre-built
        lane state dict.  With ``page_size`` set the prefilled cache is
        converted to the PAGED layout (identity page tables) before the
        decode loop runs — the one-shot road into native paged decode for
        families the scheduler does not manage (encdec, vlm); the prefill
        itself stays dense, so this is a decode-path bridge, not a
        memory-saving admission path.  Returns dict with tokens (B, max_new),
        n_generated (B,), and the final active partition (all-False when
        every lane exited).
        """
        tokens = batch["tokens"]
        b, s = tokens.shape
        lens = jnp.asarray(batch.get("lens", jnp.full((b,), s)), jnp.int32)
        max_len = max_len or (s + self.max_new_tokens)
        cache = self.make_cache(b, max_len, batch)
        sstate = self.make_state(b, sampling)

        with self.obs.span("prefill", xla=True, b=b, s=s):
            logits, cache = self._prefill(self.params,
                                          dict(batch, lens=lens), cache)
        if page_size is not None:
            cache = to_paged(self.cfg, cache, page_size=page_size,
                             pool_pages=pool_pages,
                             page_dtype=self.page_dtype)
        # all-greedy batches skip the stochastic pipeline here too (keys of
        # greedy lanes are never read, so not splitting them is inert)
        if S.is_all_greedy(sstate):
            first_tok = self._sample(logits)
        else:
            first_tok, sstate = self._sample(logits, sstate)

        max_new = self.max_new_tokens
        out = jnp.zeros((b, max_new), jnp.int32)
        out = out.at[:, 0].set(first_tok)
        budget = jnp.full((b,), max_new, jnp.int32)
        p0 = (first_tok != self.stop_token) & (budget > 1)
        # ---- single dispatch: the whole decode loop runs inside XLA ----
        with self.obs.span("decode", xla=True, b=b, n_steps=max_new):
            cache, out, tok, _, n_gen, _, _ = self._decode_chunk(
                self.params, cache, out, first_tok, p0,
                jnp.ones((b,), jnp.int32), budget, sstate, n_steps=max_new,
                stochastic=not S.is_all_greedy(sstate))
        p = tok != self.stop_token                  # lanes that never exited
        return {"tokens": out, "n_generated": n_gen, "active": p,
                "cache": cache}
