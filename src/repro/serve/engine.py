"""Batched serving engine with a fully-jitted vector-partitioned decode loop.

A batch of requests is a VECTOR (paper §2.3.4): each lane is one request.
Prefill uses ragged whilelt lengths; the decode loop is ONE jitted XLA while
loop over a shrinking active partition (§2.3.4) — per-lane stop conditions
retire lanes inside the compiled loop, so there is no per-token Python
dispatch and no cache rewriting: the model's own ``dynamic_update_slice``
writes are the only cache mutation (XLA aliases them in place).

Inactive lanes keep decoding architecturally but their effects are not
observed: sampled tokens are merging-predicated to the stop token, output
slots are write-masked, and their cache slots become garbage-beyond-pos —
harmless, because a finished lane is always refilled through
``repro.models.slot_update`` (a fresh prefill) before it is reused.  That is
the contract that makes continuous batching (see ``serve.scheduler``) a pure
lane-permutation problem.

Sampling is per-lane predicated (``repro.sample``): every lane carries its
own SamplingParams row (temperature/top-k/top-p/min-p/penalties/seed/greedy
flag) and PRNG key inside the decode carry, so heterogeneous stochastic
decoding runs in the SAME jitted while-loop — greedy lanes select the
bit-exact raw argmax under a merging predicate, and a request's stream is a
function of (seed, prompt, params) only, never of batch composition.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro import sample as S
from repro.core import predicate as P
from repro.models import (get_model, is_paged, paged_decode_ok, paged_view,
                          paged_writeback, to_paged)
from repro.sample.processors import ban_pred, mask_logits


@dataclasses.dataclass
class ServeEngine:
    cfg: object
    params: object
    max_new_tokens: int = 32
    stop_token: int = 0
    # engine-wide default sampling spec for requests/batches that don't carry
    # their own (None = greedy argmax, the bit-exact legacy behavior)
    default_sampling: Optional[S.SamplingParams] = None
    # constrained decoding: token ids masked out of EVERY lane's vocab
    # partition (greedy lanes included) before sampling
    banned_tokens: Optional[Sequence[int]] = None
    # paged decode: "native" (the default; "kernel" is a legacy alias) reads
    # K/V directly through the page table inside flash attention and
    # scatter-stores each new token into the lane's tail page — no dense-view
    # materialization on the decode hot path.  "gather" is the reference
    # oracle: materialize the dense view through the table, run the unchanged
    # family decode, scatter the one new token back (bitwise identical to the
    # dense cache BY CONSTRUCTION; tests pin the native path against it).
    paged_attn: str = "native"

    def __post_init__(self):
        if self.paged_attn not in ("native", "kernel", "gather"):
            raise ValueError(
                f"paged_attn must be 'native' ('kernel' alias) or 'gather', "
                f"got {self.paged_attn!r}")
        self.model = get_model(self.cfg)
        # logits run over the PADDED vocab (the model already predicates the
        # pad lanes to -1e30, so leaving them "allowed" here is inert)
        v = getattr(self.cfg, "padded_vocab", self.cfg.vocab_size)
        self._ban = (ban_pred(v, tuple(self.banned_tokens))
                     if self.banned_tokens else None)
        self._prefill = jax.jit(
            lambda p, b, c: self.model.prefill(p, self.cfg, b, c))
        # donate the mutable decode state (cache/out_buf/tok/p/n_gen and the
        # sampler lane state) so XLA updates it in place instead of copying
        # the KV cache every burst; the CPU backend has no donation (it
        # would only warn), so gate it
        donate = (1, 2, 3, 4, 5, 7) if jax.default_backend() != "cpu" else ()
        self._decode_chunk = jax.jit(self._decode_chunk_impl,
                                     static_argnames=("n_steps", "stochastic"),
                                     donate_argnums=donate)
        self._warned_gather_fallback = False

    def _sample(self, logits, sstate=None, out_buf=None, n_gen=None):
        """Sample one token per lane through ``repro.sample`` (the single
        sampler entry point).  With no state: bit-exact greedy argmax."""
        if sstate is None:
            return S.greedy_tokens(logits if self._ban is None else
                                   mask_logits(logits, self._ban[None, :]))
        return S.sample(logits, sstate, out_tokens=out_buf, n_out=n_gen,
                        ban=self._ban)

    def make_state(self, b: int, sampling=None) -> dict:
        """Batched sampler lane state for ``b`` lanes (falls back to the
        engine's ``default_sampling``, then to greedy)."""
        if isinstance(sampling, dict):
            return sampling
        return S.lane_state(self.default_sampling if sampling is None
                            else sampling, b)

    # ------------------------------------------------------------------
    # jitted decode loop
    # ------------------------------------------------------------------

    def _decode_chunk_impl(self, params, cache, out_buf, tok, p, n_gen,
                           lane_budget, sstate, *, n_steps: int,
                           stochastic: bool = True):
        """The decode hot loop as ONE XLA while: §2.3.4 dynamic exits.

        Every iteration decodes all lanes, but only the active partition
        commits tokens; a lane leaves the partition when it emits the stop
        token or its per-lane budget runs out.  ``n_steps`` caps the burst so
        the continuous-batching scheduler can admit queued requests between
        calls; ``generate`` passes n_steps = max_new_tokens and uniform
        budgets so the same loop serves both paths (bit-identity between the
        one-shot and scheduled engines follows by construction).

        ``sstate`` is the per-lane sampler state (``repro.sample``): keys
        split once per iteration for EVERY lane — a live lane's chain
        position therefore equals its committed token count, independent of
        chunk boundaries and co-scheduled traffic — and the whole processor
        pipeline (penalty gathers over the lane's own out_buf, top-k/top-p
        predicates, the ordered top-p cumsum) traces into this while-loop:
        no per-token host dispatch.  ``stochastic=False`` (a static flag the
        caller derives host-side: no live lane samples) compiles the legacy
        argmax-only body — greedy traffic pays zero pipeline cost and the
        sampler state passes through untouched, which is sound because a
        stochastic lane's key chain only needs to advance on steps it is
        live for, and every such step runs a stochastic=True chunk.
        Returns (cache, out_buf, tok, p, n_gen, sstate, steps_run).
        """
        stop = self.stop_token
        b, max_out = out_buf.shape
        rows = jnp.arange(b)

        def loop_cond(carry):
            _, _, _, p, _, _, step = carry
            return jnp.any(p) & (step < n_steps)

        def loop_body(carry):
            cache, out_buf, tok, p, n_gen, sstate, step = carry
            logits, cache = self._cached_decode(params, {"token": tok[:, None]},
                                                cache)
            if stochastic:
                nxt, sstate = self._sample(logits, sstate, out_buf, n_gen)
            else:
                nxt = self._sample(logits)
            nxt = P.merging(p, nxt, jnp.full_like(nxt, stop))
            col = jnp.clip(n_gen, 0, max_out - 1)
            out_buf = out_buf.at[rows, col].set(
                jnp.where(p, nxt, out_buf[rows, col]))
            n_gen = n_gen + p.astype(jnp.int32)
            p = p & (nxt != stop) & (n_gen < lane_budget)
            return cache, out_buf, nxt, p, n_gen, sstate, step + 1

        cache, out_buf, tok, p, n_gen, sstate, steps = jax.lax.while_loop(
            loop_cond, loop_body,
            (cache, out_buf, tok, p, n_gen, sstate, jnp.int32(0)))
        return cache, out_buf, tok, p, n_gen, sstate, steps

    def _cached_decode(self, params, batch, cache):
        """One decode step against a dense OR paged cache.

        Paged "native" (default; "kernel" accepted as a legacy alias): the
        family's decode reads K/V through the page table inside flash
        attention and scatter-stores its new token straight into the lane's
        tail page — no dense-view materialization on the hot path.  Paged
        "gather" (the reference oracle): gather-load the dense view through
        the table, run the family's unchanged decode, scatter-store the new
        token back — bitwise equal to the dense engine because the view IS
        the dense cache.  All of it traces into the jitted decode loop.
        """
        if not is_paged(cache):
            return self.model.decode(params, self.cfg, batch, cache)
        if self.paged_attn != "gather":
            if paged_decode_ok(self.cfg):
                return self.model.decode(params, self.cfg, batch, cache)
            if not self._warned_gather_fallback:
                # trace-time emission: fires once per engine, not per step
                warnings.warn(
                    f"family '{self.cfg.family}' has no native paged decode; "
                    "falling back to the gather bridge (dense view "
                    "materialized through the page table every step)",
                    RuntimeWarning, stacklevel=2)
                self._warned_gather_fallback = True
        view = paged_view(self.cfg, cache)
        pos = view["pos"]
        logits, view = self.model.decode(params, self.cfg, batch, view)
        return logits, paged_writeback(self.cfg, cache, view, pos)

    # ------------------------------------------------------------------
    # one-shot batch API
    # ------------------------------------------------------------------

    def make_paged_cache(self, b: int, max_len: int, *, page_size: int,
                         pool_pages: int, batch: Optional[dict] = None):
        """Allocate a paged cache: shared page pools + per-lane page table."""
        if self.cfg.family == "encdec":
            return self.model.make_paged_cache(
                self.cfg, b, max_len, src_len=batch["src_emb"].shape[1],
                page_size=page_size, pool_pages=pool_pages)
        return self.model.make_paged_cache(self.cfg, b, max_len,
                                           page_size=page_size,
                                           pool_pages=pool_pages)

    def make_cache(self, b: int, max_len: int, batch: Optional[dict] = None):
        """Allocate a cache for ``b`` request lanes (family-dispatched)."""
        if self.cfg.family == "encdec":
            return self.model.make_cache(self.cfg, b, max_len,
                                         src_len=batch["src_emb"].shape[1])
        if self.cfg.family == "ssm":
            return self.model.make_cache(self.cfg, b)
        return self.model.make_cache(self.cfg, b, max_len)

    def generate(self, batch, *, max_len: Optional[int] = None,
                 sampling=None, page_size: Optional[int] = None,
                 pool_pages: Optional[int] = None):
        """batch: {"tokens": (B, S) prompts, "lens": (B,)} (+ modality extras).

        ``sampling`` is None (engine default / greedy), one ``SamplingParams``
        broadcast over lanes, a per-lane sequence of them, or a pre-built
        lane state dict.  With ``page_size`` set the prefilled cache is
        converted to the PAGED layout (identity page tables) before the
        decode loop runs — the one-shot road into native paged decode for
        families the scheduler does not manage (encdec, vlm); the prefill
        itself stays dense, so this is a decode-path bridge, not a
        memory-saving admission path.  Returns dict with tokens (B, max_new),
        n_generated (B,), and the final active partition (all-False when
        every lane exited).
        """
        tokens = batch["tokens"]
        b, s = tokens.shape
        lens = jnp.asarray(batch.get("lens", jnp.full((b,), s)), jnp.int32)
        max_len = max_len or (s + self.max_new_tokens)
        cache = self.make_cache(b, max_len, batch)
        sstate = self.make_state(b, sampling)

        logits, cache = self._prefill(self.params, dict(batch, lens=lens), cache)
        if page_size is not None:
            cache = to_paged(self.cfg, cache, page_size=page_size,
                             pool_pages=pool_pages)
        # all-greedy batches skip the stochastic pipeline here too (keys of
        # greedy lanes are never read, so not splitting them is inert)
        if S.is_all_greedy(sstate):
            first_tok = self._sample(logits)
        else:
            first_tok, sstate = self._sample(logits, sstate)

        max_new = self.max_new_tokens
        out = jnp.zeros((b, max_new), jnp.int32)
        out = out.at[:, 0].set(first_tok)
        budget = jnp.full((b,), max_new, jnp.int32)
        p0 = (first_tok != self.stop_token) & (budget > 1)
        # ---- single dispatch: the whole decode loop runs inside XLA ----
        cache, out, tok, _, n_gen, _, _ = self._decode_chunk(
            self.params, cache, out, first_tok, p0, jnp.ones((b,), jnp.int32),
            budget, sstate, n_steps=max_new,
            stochastic=not S.is_all_greedy(sstate))
        p = tok != self.stop_token                  # lanes that never exited
        return {"tokens": out, "n_generated": n_gen, "active": p,
                "cache": cache}
