"""Batched serving engine with vector-partitioned early exit.

A batch of requests is a VECTOR (paper §2.3.4): each lane is one request.
Prefill uses ragged whilelt lengths; the decode loop runs under a shrinking
active partition — a lane goes inactive when it emits a stop token (brkb over
the stop predicate) or exhausts its token budget.  Inactive lanes are
merging-predicated: their state stops changing while the rest of the batch
continues (no recompilation, no batch compaction needed at this scale;
compaction hooks exist for fleet-scale continuous batching).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import partition as PT
from repro.core import predicate as P
from repro.models import get_model


@dataclasses.dataclass
class ServeEngine:
    cfg: object
    params: object
    max_new_tokens: int = 32
    stop_token: int = 0
    greedy: bool = True

    def __post_init__(self):
        self.model = get_model(self.cfg)
        self._prefill = jax.jit(
            lambda p, b, c: self.model.prefill(p, self.cfg, b, c))
        self._decode = jax.jit(
            lambda p, b, c: self.model.decode(p, self.cfg, b, c))

    def _sample(self, logits):
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def generate(self, batch, *, max_len: Optional[int] = None):
        """batch: {"tokens": (B, S) prompts, "lens": (B,)} (+ modality extras).

        Returns dict with tokens (B, max_new), n_generated (B,), and the
        final active partition (all-False when every lane exited).
        """
        tokens = batch["tokens"]
        b, s = tokens.shape
        lens = jnp.asarray(batch.get("lens", jnp.full((b,), s)), jnp.int32)
        max_len = max_len or (s + self.max_new_tokens)
        if self.cfg.family == "encdec":
            cache = self.model.make_cache(self.cfg, b, max_len,
                                          src_len=batch["src_emb"].shape[1])
        elif self.cfg.family == "ssm":
            cache = self.model.make_cache(self.cfg, b)
        else:
            cache = self.model.make_cache(self.cfg, b, max_len)

        logits, cache = self._prefill(self.params, dict(batch, lens=lens), cache)
        first_tok = self._sample(logits)

        # ---- vector-partitioned decode loop ----
        out = jnp.zeros((b, self.max_new_tokens), jnp.int32)
        out = out.at[:, 0].set(first_tok)
        p0 = P.ptrue(b)
        # lanes whose first token is already a stop exit immediately (brkb
        # semantics are per-lane here: the partition is a conjunction over
        # time, not over lanes, so each lane just clears itself)
        p_active = p0 & (first_tok != self.stop_token)

        def body_fn(state, p):
            out, cache, tok, t = state
            logits, new_cache = self._decode(self.params, {"token": tok[:, None]},
                                             cache)
            nxt = self._sample(logits)
            # merging predication: inactive lanes keep old outputs & cache pos
            nxt = P.merging(p, nxt, jnp.zeros_like(nxt))
            out = out.at[:, t].set(jnp.where(p & (t < self.max_new_tokens),
                                             nxt, out[:, t]))
            cache = jax.tree.map(
                lambda new, old: _merge_cache(p, new, old), new_cache, cache)
            return out, cache, nxt, t + 1

        state = (out, cache, first_tok, jnp.int32(1))
        # engine-level loop (each step jitted); the active partition shrinks
        # as lanes hit their stop token — paper §2.3.4 dynamic exits
        p = p_active
        while bool(jnp.any(p)) and int(state[3]) < self.max_new_tokens:
            state = body_fn(state, p)
            nxt = state[2]
            p = p & (nxt != self.stop_token)
        out, cache, _, t = state
        n_gen = jnp.minimum(
            jnp.argmax(jnp.concatenate(
                [out == self.stop_token,
                 jnp.ones((b, 1), bool)], axis=1), axis=1) + 1,
            self.max_new_tokens)
        return {"tokens": out, "n_generated": n_gen, "active": p,
                "cache": cache}


def _merge_cache(p, new, old):
    """Predicated cache merge: lane-inactive rows keep their old cache."""
    if new.ndim == 0 or new.shape == ():
        return new
    # find the batch axis: caches are (*stack, B, ...) or (B,) for pos
    if old.dtype == jnp.int32 and old.ndim == 1:      # pos (B,)
        return jnp.where(p, new, old)
    # batch axis is ndim-4 for KV (.., B, H, S, D), ndim-... — broadcast mask
    # over trailing dims at the axis whose size matches p
    for ax in range(new.ndim):
        if new.shape[ax] == p.shape[0]:
            shape = [1] * new.ndim
            shape[ax] = p.shape[0]
            return jnp.where(p.reshape(shape), new, old)
    return new
