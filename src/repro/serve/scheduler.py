"""Continuous-batching scheduler: SVE compact/partition semantics for traffic.

The serving batch is a vector of request LANES.  A lane's lifecycle is the
paper's §2.3.4 partition algebra applied to traffic instead of loop strips:

  * **admission** — a queued request is prefilled (as part of a sub-batch)
    and spliced into a free lane via ``repro.models.slot_update``: a pure
    index scatter along each cache array's declared lane axis.
  * **decode** — the engine's jitted ``_decode_chunk`` runs bounded bursts;
    per-lane stop tokens / budgets shrink the active partition *inside* XLA.
  * **harvest** — lanes that left the partition surrender their tokens and
    become free slots.
  * **compaction** — when occupancy drops below ``compact_threshold``, the
    survivors are squeezed into the lowest-numbered lanes with the SVE
    ``compact`` permutation (``partition.compact_perm``) applied to the cache
    (``gather_lanes``) and every per-lane side table.  Lanes stay dense, so
    admission always splices into the tail and throughput is a function of
    ACTIVE lanes, not peak batch size.

With ``page_size`` set the cache is PAGED (SVE §2.3.3 gather/scatter): each
lane addresses logical token blocks through a per-lane page table while the
physical pages live in a shared ref-counted pool.  Admission is then gated on
PAGE availability, not lane count — memory, not the lane vector, is the
capacity currency — and a prefix index lets a request whose prompt prefix is
already resident skip prefill for the shared pages (refcount bump + suffix
prefill).  Compacting lanes never moves a page: only the table rows permute.

With ``host_swap_pages`` set, the prefix cache grows an EVICTION TIER: a
shared-prefix page whose refcount drops to zero is spilled to a host-side
LRU store (content-addressed by its full prefix token bytes) instead of
being forgotten, and a later request whose prompt walks the same prefix
pages it back in — fresh pool pages, one batched scatter, re-registered in
the radix index.  The prefix cache thereby outlives lane residency and
becomes a cross-REQUEST session cache: turn N+1 of a conversation hits the
prefix that turn N retired minutes ago.  A quantized pool spills its narrow
bytes plus scales, so page-in restores the pool rows bit-exactly.

Everything that moves request state is an index gather/scatter; nothing is
recompiled when traffic gets ragged — the vector-length-agnostic contract.

The default serve path is the FUSED step program (``fused=True``): one round's
prefill chunk(s), admission tail and decode burst trace into a SINGLE XLA
dispatch (``ServeEngine._fused_step``), so the host's per-round work is pure
bookkeeping — the scalar loop tail the paper's VLA model eliminates at
instruction level, eliminated at dispatch level.  With ``overlap=True`` the
host loop goes ASYNC on top: round N+1 is dispatched before round N's results
are read back, and the one blocking sync per round harvests the PREVIOUS
round from prefetched handles — admission plans against a one-round-stale
lane view, which only under-reports free lanes (token streams are
batch-composition independent, so results are unchanged).
"""

from __future__ import annotations

import collections
import dataclasses
import enum
import time
import zlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sample as S
from repro.dist import serve as DS
from repro.core import paging as PG
from repro.models import (chunked_prefill_granularity, chunked_prefill_ok,
                          gather_lanes, get_model, lane_independent_decode,
                          slot_update)
from repro.obs import Obs

from .engine import ServeEngine

#: every scheduler stat, registered as a typed metric in the obs registry
#: (``(name, snapshot key)``; None = same).  ``stats`` is a dict view over
#: these, so ``stats["x"] += 1`` call sites and tests keep working while
#: ``obs.metrics.snapshot()`` is the single summary definition the bench
#: records.
_STAT_COUNTERS = (
    ("steps", "rounds"), ("decode_steps", None), ("lane_steps", None),
    ("active_lane_steps", None), ("compactions", None),
    ("prefix_hits", None), ("prefix_hit_tokens", None),
    ("prefill_tokens", None), ("page_waits", None), ("prefill_chunks", None),
    ("dispatches", None), ("host_syncs", None), ("swap_out_pages", None),
    ("swap_in_pages", None), ("session_hits", None),
    ("session_hit_tokens", None),
    # request-lifecycle robustness counters (deadlines / cancellation /
    # preemption / shedding / swap integrity)
    ("preemptions", None), ("shed", None), ("cancelled", None),
    ("deadline_misses", None), ("resume_page_ins", None),
    ("swap_checksum_failures", None),
)


class FinishReason(str, enum.Enum):
    """Why a request's result is what it is.  Every entry in ``run()``'s
    results carries one under ``"finish_reason"``; the str values are what
    lands in bench JSON / logs.  ``PREEMPTED_RESUMED`` marks a request that
    finished normally but was preempted (and bit-exactly resumed) at least
    once along the way — its tokens are still byte-identical to an
    uninterrupted run."""
    DONE = "done"
    CANCELLED = "cancelled"
    DEADLINE = "deadline"
    SHED = "shed"
    PREEMPTED_RESUMED = "preempted_resumed"


class RequestRejected(ValueError):
    """Typed rejection raised by ``submit`` for a request that could NEVER
    be admitted (prompt longer than the lane cache, or a worst-case page
    demand the pool cannot cover even when empty) — fail at the front door
    instead of queueing a request that waits forever."""


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class PageAllocator:
    """Ref-counted physical page allocator with a LIFO free list.

    Invariants (property-tested in tests/test_page_allocator.py): a page is
    either free or has refcount >= 1; ``alloc`` is all-or-nothing; releasing
    to zero returns the page to the free list exactly once (double release
    raises); free + live == pool_pages at all times.
    """

    def __init__(self, pool_pages: int):
        self.pool_pages = pool_pages
        self._free = list(range(pool_pages - 1, -1, -1))
        self.refcount = np.zeros((pool_pages,), np.int64)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        return self.pool_pages - len(self._free)

    def alloc(self, n: int):
        """n fresh pages with refcount 1, or None if the pool can't cover n."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            assert self.refcount[p] == 0, f"page {p} on free list with refs"
            self.refcount[p] = 1
        return pages

    def retain(self, page: int):
        """Bump the refcount of a RESIDENT page (prefix sharing)."""
        if self.refcount[page] <= 0:
            raise ValueError(f"retain of free page {page}")
        self.refcount[page] += 1

    def release(self, page: int) -> bool:
        """Drop one reference; True if the page returned to the free list."""
        if self.refcount[page] <= 0:
            raise ValueError(f"double free of page {page}")
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self._free.append(page)
            return True
        return False


def _entry_crc(entry: dict) -> int:
    """Content checksum of one swap-store entry (all pool blocks, key-sorted
    so the digest is layout-stable)."""
    crc = 0
    for k in sorted(entry):
        crc = zlib.crc32(np.ascontiguousarray(entry[k]).tobytes(), crc)
    return crc


class HostSwapStore:
    """Host-side LRU store of evicted prefix pages (the swap tier).

    Entries are content-addressed by the FULL prefix token bytes up to and
    including the page's block — unlike the resident radix index, no parent
    page identity is needed: the whole token history is in the key, which
    is sound across page-id recycling and scheduler restarts.  Each entry
    holds one page's pool blocks as numpy arrays ``{pool_key: (lead +
    (Hkv, ps[, D]))}`` — quantized pools store narrow bytes + scales, so
    page-in is bit-exact.  Capacity is counted in PAGES; insertion past
    capacity evicts least-recently-used entries.

    Every entry carries a CRC taken at ``put`` time and verified at ``get``:
    a corrupted entry (host memory fault, or the chaos harness flipping
    bytes) is dropped and ``get`` returns None, so the planner's swap-chain
    walk simply stops extending there and the request cold-prefills the
    rest — degraded latency, NEVER wrong tokens.
    """

    def __init__(self, max_pages: int):
        if max_pages < 1:
            raise ValueError(f"host_swap_pages must be >= 1, got {max_pages}")
        self.max_pages = max_pages
        self._store: collections.OrderedDict = collections.OrderedDict()
        self._crc: dict = {}
        self.evictions = 0
        self.checksum_failures = 0

    def __len__(self):
        return len(self._store)

    def __contains__(self, key: bytes) -> bool:
        return key in self._store

    def get(self, key: bytes):
        """The entry for ``key`` (refreshed to most-recently-used), or None.
        An entry whose content no longer matches its put-time CRC is deleted
        and reported as None (counted in ``checksum_failures``)."""
        entry = self._store.get(key)
        if entry is None:
            return None
        if _entry_crc(entry) != self._crc[key]:
            del self._store[key]
            del self._crc[key]
            self.checksum_failures += 1
            return None
        self._store.move_to_end(key)
        return entry

    def put(self, key: bytes, entry: dict):
        """Insert a spilled page (no-op refresh when already stored — the
        content under a full-prefix key can never change)."""
        if key in self._store:
            self._store.move_to_end(key)
            return
        self._store[key] = entry
        self._crc[key] = _entry_crc(entry)
        while len(self._store) > self.max_pages:
            k, _ = self._store.popitem(last=False)
            self._crc.pop(k, None)
            self.evictions += 1


class PrefixIndex:
    """Radix-style map from (parent page, token block) to a resident page.

    A prompt's K/V pages are content-addressed by their token block AND the
    identity of the parent page (which transitively pins the whole prefix —
    K/V of a block depends on every token before it, so token bytes alone are
    not a sound key).  Entries exist only while their page is resident; when
    a page dies its subtree is unindexed so a recycled page id can never be
    mistaken for the old prefix.
    """

    def __init__(self):
        self._child: dict = {}                         # (parent, bytes) -> page
        self._key_of: dict = {}                        # page -> its key
        self._kids: dict = collections.defaultdict(set)  # parent -> pages
        self._prefix_of: dict = {}      # page -> full prefix bytes (swap key)

    def __len__(self):
        return len(self._child)

    def lookup(self, tokens: np.ndarray, page_size: int) -> list:
        """Longest resident chain of full prompt pages (possibly empty)."""
        chain = []
        parent = -1
        for j in range(len(tokens) // page_size):
            key = (parent, tokens[j * page_size:(j + 1) * page_size].tobytes())
            page = self._child.get(key)
            if page is None:
                break
            chain.append(page)
            parent = page
        return chain

    def register(self, parent: int, block: np.ndarray, page: int,
                 prefix: Optional[bytes] = None):
        """Index ``page`` under ``(parent, block bytes)``; ``prefix`` is the
        FULL prompt byte string through this block, kept so an eviction tier
        can content-address the page when it later spills to host."""
        key = (parent, block.tobytes())
        if key in self._child:          # identical block admitted concurrently
            return
        self._child[key] = page
        self._key_of[page] = key
        self._kids[parent].add(page)
        if prefix is not None:
            self._prefix_of[page] = prefix

    def prefix_of(self, page: int) -> Optional[bytes]:
        """Full prefix token bytes of an indexed page (the host-swap key),
        or None when the page is unindexed."""
        return self._prefix_of.get(page)

    def drop(self, page: int):
        """Unindex a dying page and (recursively) its indexed subtree."""
        key = self._key_of.pop(page, None)
        self._prefix_of.pop(page, None)
        if key is not None:
            self._child.pop(key, None)
            self._kids[key[0]].discard(page)
        for child in list(self._kids.pop(page, ())):
            self.drop(child)


@dataclasses.dataclass
class _PagePlan:
    """Admission plan for one request under the paged cache."""
    shared: list                        # resident prefix pages (refs taken)
    swapped: list                       # fresh pages paged in from host swap
    new: list                           # freshly allocated pages
    budget: int                         # decode token budget
    plen: int                           # full prompt length
    pos0: int                           # (len(shared)+len(swapped)) * page_size


@dataclasses.dataclass
class _Partial:
    """A request whose admission prefill is being run in CHUNKS interleaved
    with decode rounds (chunked prefill).  It owns a reserved lane (marked
    pending: excluded from decode, harvest and admission) and — under paging
    — its full page reservation; the dense prefill sub-cache accumulates
    K/V chunk by chunk until the final chunk's logits seed decode and the
    whole state splices into the lane."""
    req: Request
    plan: Optional[_PagePlan]           # page reservation (None = dense cache)
    lane: int
    sub_cache: dict                     # 1-lane dense cache being chunk-filled
    done: int                           # suffix tokens prefilled so far
    pos0: int                           # prefix-shared start offset
    budget: int
    # prefix-seed arrays (seed_tab, seed_len), consumed by the FIRST chunk:
    # seeding must read the live cache AFTER the donor's page install has
    # executed — at _start_partial time that install may still be riding the
    # current round's fused dispatch
    seed: Optional[tuple] = None


@dataclasses.dataclass
class _AdmitPlan:
    """Host-side plan for one round's admission sub-batch: everything the
    device tail needs, produced without touching the device (shared by the
    legacy executor and the fused-step assembly)."""
    reqs: list
    plans: list                         # _PagePlan per req (paged) or []
    lanes: np.ndarray                   # (n,) target lanes
    n: int
    n_pad: int                          # pow2-bucketed row count
    toks: np.ndarray                    # (n_pad, plen_pad)
    lens: np.ndarray                    # (n_pad,)
    pos0_pad: np.ndarray                # (n_pad,)
    budgets: np.ndarray                 # (n,)
    specs: list                         # effective SamplingParams per req


@dataclasses.dataclass
class _PartStep:
    """One chunk of one chunked-prefill partial, planned for this round."""
    part: _Partial
    batch: dict                         # numpy arrays (tokens/lens/pos0/+extras)
    final: bool
    seed: Optional[tuple] = None        # first-chunk prefix seed (tab, len)


@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival`` is in scheduler decode-step units
    (0 = available immediately); the scheduler never admits a request before
    its arrival time, which is what the Poisson serving benchmark drives.
    ``priority`` orders admission (higher first; FIFO within a level) and
    arms preemption: a page-starved higher-priority request may evict a
    strictly-lower-priority resident lane.  ``deadline`` / ``ttft_deadline``
    are absolute decode-step timestamps (same clock as ``arrival``) by which
    the request must finish / produce its first token — infeasible requests
    are SHED at admission time, resident ones past ``deadline`` retire with
    partial output."""
    rid: int
    tokens: np.ndarray                      # (S,) prompt token ids
    max_new_tokens: Optional[int] = None    # default: engine budget
    arrival: float = 0.0
    extras: Optional[dict] = None           # modality extras (cross_emb, ...)
    sampling: Optional[S.SamplingParams] = None  # default: engine default
    priority: int = 0
    deadline: Optional[float] = None        # absolute finish deadline (steps)
    ttft_deadline: Optional[float] = None   # absolute first-token deadline


@dataclasses.dataclass
class PreemptedState:
    """Complete host-side state of a preempted mid-decode request: its page
    blocks (spilled through the same batched gather the host-swap tier
    uses), dense lane carries, decode rows and sampler-state row.  Resuming
    splices everything back bit-exactly — the per-lane PRNG chain position
    is the committed token count, so the resumed stream continues as if the
    preemption never happened."""
    req: Request
    dense: dict                             # per-lane cache carries (host)
    blocks: Optional[dict]                  # page-chain pool blocks (host)
    n_pages: int                            # pages to re-allocate at resume
    row: dict                               # out/tok/ngen/budget rows (host)
    srow: dict                              # sampler-state row (host)
    stoch: bool                             # lane sampled stochastically
    order: int                              # preemption sequence number


class ContinuousBatchingScheduler:
    """Serve a stream of requests over a fixed-capacity lane vector.

    Parameters
    ----------
    engine: a ``ServeEngine`` (supplies the jitted prefill/decode-chunk fns).
    capacity: number of request lanes (the vector length of the batch).
    max_len: cache sequence capacity per lane (>= prompt + budget).  Under
        paging it is rounded UP to a page multiple; pass a multiple of
        ``page_size`` when bit-comparing against a dense engine of the same
        max_len (the gathered view length then matches exactly).
    chunk: decode steps per burst between admission opportunities.
    compact_threshold: occupancy fraction below which live lanes are
        compacted to the front (the knob; 0 disables compaction).
    page_size: tokens per KV page — enables the PAGED cache: admission is
        gated on free pages, memory is the capacity currency.  None = dense.
    pool_pages: physical pages in the pool (default: capacity * pages-per-
        lane, i.e. the dense memory footprint; smaller values trade
        admission concurrency for memory).
    prefix_sharing: admit a request whose prompt prefix is already resident
        by bumping page refcounts and prefilling only the suffix (families
        whose full prefix state lives in paged KV only).
    host_swap_pages: capacity (in pages) of the host-side LRU swap store —
        enables the EVICTION TIER: shared-prefix pages that release to
        refcount zero spill to host instead of being forgotten, and a later
        request whose prompt walks a spilled prefix pages it back in (fresh
        pool pages + one batched scatter) and skips its prefill.  Turns the
        prefix cache into a cross-request session cache.  Requires paging +
        prefix sharing; None/0 disables.
    prefill_chunk: split admission prefill into chunks of at most this many
        tokens, interleaved with decode rounds — a long prompt no longer
        freezes resident lanes for its whole prefill.  The chunked request
        holds a reserved lane (and, under paging, its full page reservation)
        while its K/V accumulates.  For dense-family models tokens are
        identical to whole-prompt prefill unconditionally (``pos0``
        suffix-prefill numerics depend only on absolute positions and the
        cache extent); for MoE the identity additionally requires that
        expert capacity never drops — per-chunk dispatch groups see
        different co-tokens, the same batch-composition sensitivity ALL MoE
        admission batching has (size ``capacity_factor`` accordingly).
        Families declare ``CHUNKED_PREFILL_OK`` (all five now do) and a
        ``chunked_prefill_granularity`` the chunk must be a multiple of
        (ssm/hybrid: ``ssm_chunk``, so the resumed SSD scan replays the
        same chunk_step sequence as the unchunked scan).  None =
        whole-prompt prefill.
    fused: run each round's prefill chunk(s) + admission + decode burst as
        ONE jitted dispatch (``ServeEngine._fused_step``) instead of
        separate prefill / decode dispatches.  Bit-identical to the unfused
        loop (same ops, same order; padded admission rows splice through
        index scatters whose out-of-range lanes drop).
    overlap: async host loop — dispatch round N+1 before reading round N's
        results, then harvest round N from prefetched host copies: ONE
        blocking sync per round.  Admission sees a one-round-stale lane
        view (under-reports free lanes only); ``finished_at`` timestamps
        shift by the harvest delay.  Requires ``fused``.
    src_len: encoder memory length for encdec serving (every request's
        ``src_emb`` extra is zero-padded to this length at submit; required
        for the encdec family, ignored otherwise).
    max_queue: bounded admission queue — a ``submit`` past this many queued
        requests is SHED immediately (recorded result with
        ``finish_reason="shed"``) instead of queueing unboundedly under
        overload.  None = unbounded (the default).
    obs: an ``repro.obs.Obs`` handle — its metrics registry backs ``stats``
        and, when it carries a tracer, the round/request timeline is
        recorded at the host-side seams (never inside jitted code, never
        adding a device sync).  Default: a FRESH metrics-only handle —
        callers that want engine + scheduler + bench in one registry (the
        launcher, the bench's traced legs) pass one explicitly; sharing one
        obs across scheduler instances accumulates their counters.
    """

    def __init__(self, engine: ServeEngine, *, capacity: int, max_len: int,
                 chunk: int = 8, compact_threshold: float = 0.5,
                 page_size: Optional[int] = None,
                 pool_pages: Optional[int] = None,
                 prefix_sharing: bool = True,
                 host_swap_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 fused: bool = True, overlap: bool = False,
                 src_len: Optional[int] = None,
                 max_queue: Optional[int] = None,
                 obs: Optional[Obs] = None):
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if engine.cfg.family == "encdec" and src_len is None:
            raise ValueError(
                "encdec serving needs src_len= (the padded encoder memory "
                "length caches are allocated for)")
        if overlap and not fused:
            raise ValueError("overlap=True requires fused=True (the async "
                             "harvest hangs off the fused dispatch handles)")
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
            if not chunked_prefill_ok(engine.cfg):
                raise ValueError(
                    f"family '{engine.cfg.family}' does not support chunked "
                    "prefill (needs pos0 suffix-prefill with all cross-chunk "
                    "state in the KV cache)")
            gran = chunked_prefill_granularity(engine.cfg)
            if prefill_chunk % gran:
                raise ValueError(
                    f"prefill_chunk={prefill_chunk} must be a multiple of "
                    f"family '{engine.cfg.family}' chunked-prefill "
                    f"granularity {gran} (chunk boundaries off the SSD scan "
                    "grid would replay a different chunk_step sequence)")
        self.engine = engine
        self.fused = fused
        self.overlap = overlap
        self.src_len = src_len
        self.capacity = capacity
        self.chunk = chunk
        self.compact_threshold = compact_threshold
        self.page_size = page_size
        self.prefill_chunk = prefill_chunk
        self._partials: list[_Partial] = []

        self.queue: collections.deque[Request] = collections.deque()
        self.results: dict[int, dict] = {}
        self._next_rid = 0
        self.now = 0.0                       # decode-step clock
        self.max_queue = max_queue
        # request-lifecycle control plane: live requests by rid (queued,
        # pending, resident or preempted), spilled preempted state awaiting
        # re-admission, and how often each rid was preempted (a finished
        # request with a nonzero count reports PREEMPTED_RESUMED)
        self._live_req: dict[int, Request] = {}
        self._preempted: list[PreemptedState] = []
        self._rid_preempts: dict[int, int] = {}
        self._preempt_seq = 0

        b = capacity
        self.lane_rid = np.full((b,), -1, np.int64)   # -1 = free lane
        if page_size is not None:
            self.n_pages = PG.pages_needed(max_len, page_size)
            max_len = self.n_pages * page_size
            self.pool_pages = pool_pages or capacity * self.n_pages
            # one RESERVED page past the allocatable pool: lanes that are
            # free or retired still decode architecturally inside the jitted
            # chunk, and their clamped writes must never land in a page a
            # live request owns — their table rows all point at the trash
            # page (the garbage-beyond-pos contract, relocated)
            self.trash_page = self.pool_pages
            self.cache = engine.make_paged_cache(
                b, max_len, page_size=page_size,
                pool_pages=self.pool_pages + 1, src_len=src_len)
            self.cache["page_table"] = jnp.full_like(
                self.cache["page_table"], self.trash_page)
            self.allocator = PageAllocator(self.pool_pages)
            self.prefix_index = PrefixIndex()
            self.prefix_sharing = prefix_sharing and getattr(
                get_model(engine.cfg), "PAGED_PREFIX_OK", False)
            self.host_swap = (HostSwapStore(host_swap_pages)
                              if host_swap_pages and self.prefix_sharing
                              else None)
            self.lane_pages: dict[int, list] = {}     # lane -> held page ids
        else:
            if host_swap_pages:
                raise ValueError("host_swap_pages needs a paged cache "
                                 "(set page_size)")
            self.cache = engine.make_cache(b, max_len, src_len=src_len)
            self.prefix_sharing = False
            self.host_swap = None
        self.max_len = max_len
        max_out = engine.max_new_tokens
        self.out_buf = jnp.zeros((b, max_out), jnp.int32)
        self.tok = jnp.full((b,), engine.stop_token, jnp.int32)
        self.p = jnp.zeros((b,), bool)                # active partition
        self.n_gen = jnp.zeros((b,), jnp.int32)
        self.budget = jnp.zeros((b,), jnp.int32)
        # per-lane sampler state rides the decode carry; a request's row is
        # spliced in at admission and moves with its lane under compaction,
        # so its key chain (and thus its token stream) is a function of its
        # own seed only, never of batch composition.  _lane_stoch is the
        # host-side shadow of which lanes actually sample — when none do,
        # the decode chunk compiles the argmax-only (legacy-cost) body.
        self.sstate = S.greedy_state(b)
        self._lane_stoch = np.zeros((b,), bool)
        # families whose decode has no cross-lane coupling let the fused
        # burst narrow to the occupied pow2 lane bucket (SVE predicate
        # narrowing on the batch axis); MoE's shared expert capacity forbids it
        self._lane_independent = lane_independent_decode(engine.cfg)
        # pending = reserved by a chunk-prefilling request: occupied (never
        # recycled, moves coherently under compaction) but excluded from
        # decode commits and harvest until its final chunk splices in
        self._lane_pending = np.zeros((b,), bool)
        # the stats dict is a VIEW over typed metrics in the obs registry:
        # same indexing/mutation surface as the old free-form dict, but the
        # registry's snapshot() is now the single summary definition
        self.obs = obs if obs is not None else Obs()
        reg = self.obs.metrics
        for name, key in _STAT_COUNTERS:
            reg.counter(name, key=key)
        reg.series("occupancy_trace", key="mean_occupancy")
        reg.series("page_occupancy_trace", key="mean_page_occupancy")
        # queue-wait-to-first-token in DECODE STEPS (observed at admission:
        # the first token commits in the admitting dispatch, so TTFT-in-steps
        # == now - arrival).  The streaming p50 is the deadline-feasibility
        # estimate admission shedding uses.
        self._ttft_hist = reg.histogram("ttft_steps", unit="steps",
                                        percentiles=(50,))
        self.stats = reg.stats_view()
        # async-overlap state: the in-flight round's result handles (with
        # host copies prefetched) plus the lane view they were dispatched
        # under; harvested one round late at the single blocking sync
        self._stash: Optional[dict] = None
        # host mirror of the device n_gen at the last harvest point — what
        # the legacy loop read back as gen_before (stale rows of free lanes
        # included), kept so active_lane_steps accounting never needs an
        # extra device sync
        self._host_ngen = np.zeros((b,), np.int64)
        # lanes whose admission/final-chunk splice rides THIS round's
        # dispatch (their n_gen becomes 1 in-flight)
        self._round_admitted: list[int] = []
        # wall-clock request timestamps: submitted -> first_token (measured
        # at the dispatch that commits the first token) -> finished (at
        # harvest); the serving benchmark derives TTFT/TPOT from these
        self.req_times: dict[int, dict] = {}
        # mesh-sharded serving: resolve the canonical placement of every
        # serve-state array ONCE (pools over "model" KV-head shards, lanes
        # over "data") and pin the state there.  Host-path mutations
        # (compaction gathers, harvest's page-table scatter) can drift an
        # array off this placement, which would retrace the fused step —
        # ``_reshard`` pins everything back before each dispatch (a no-op
        # copy when already canonical).
        self._mesh = getattr(engine, "mesh", None)
        if self._mesh is not None:
            self._cache_sh = DS.cache_shardings(engine.cfg, self.cache,
                                                self._mesh)
            lanes = (self.out_buf, self.tok, self.p, self.n_gen, self.budget)
            self._lane_sh = DS.lane_shardings(lanes, self._mesh)
            self._sstate_sh = DS.lane_shardings(self.sstate, self._mesh)
            self._reshard()

    def _reshard(self):
        """Pin the serve state to its canonical mesh placement (no-op when
        unsharded or already canonical)."""
        if self._mesh is None:
            return
        self.cache = jax.device_put(self.cache, self._cache_sh)
        (self.out_buf, self.tok, self.p, self.n_gen,
         self.budget) = jax.device_put(
            (self.out_buf, self.tok, self.p, self.n_gen, self.budget),
            self._lane_sh)
        self.sstate = jax.device_put(self.sstate, self._sstate_sh)

    def _block_on(self, tree, what: str):
        """THE single place the serve loop blocks on device results.

        Materializes every leaf of ``tree`` to numpy (one blocking sync
        point, however many arrays ride it), counts it in ``host_syncs`` and
        traces it as a ``sync`` span — so the sync accounting is measured at
        the choke point instead of asserted by magic numbers at call sites.
        """
        self.stats["host_syncs"] += 1
        with self.obs.span("sync", what=what):
            return jax.tree_util.tree_map(np.asarray, tree)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def submit(self, tokens, *, max_new_tokens: Optional[int] = None,
               arrival: float = 0.0, extras: Optional[dict] = None,
               sampling: Optional[S.SamplingParams] = None,
               priority: int = 0, deadline: Optional[float] = None,
               ttft_deadline: Optional[float] = None) -> int:
        """Queue a request; returns its rid (key into ``run()``'s results).

        ``tokens`` is the 1-D int prompt (<= ``max_len``).  ``arrival`` is
        the decode-step timestamp before which the request is not admissible
        (0.0 = immediately); the bench uses it to replay Poisson / session
        traces deterministically.  ``max_new_tokens`` caps this request's
        decode budget below the engine default; ``sampling`` carries the
        request's own decoding distribution (None: engine default/greedy) —
        lanes with different distributions coexist in one burst.  ``extras``
        holds per-request side inputs (encdec: ``src_emb``/``src_lens``).
        ``priority`` orders admission and arms preemption (see ``Request``);
        ``deadline`` / ``ttft_deadline`` are absolute decode-step timestamps
        the request must finish / first-token by — infeasible ones are shed.
        Submission never touches the device; planning happens at admission.

        Raises :class:`RequestRejected` for a request that could NEVER be
        admitted (over-long prompt, or a worst-case page demand above the
        whole pool) — fail fast instead of queueing it forever.  A request
        past a full ``max_queue`` bound is not an error: it is recorded
        immediately as a ``shed`` result and its rid returned.
        """
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim != 1:
            raise ValueError(f"prompt must be 1-D, got shape {tokens.shape}")
        if len(tokens) > self.max_len:
            raise RequestRejected(
                f"prompt length {len(tokens)} exceeds lane capacity "
                f"max_len={self.max_len}")
        if self.page_size is not None:
            own = (self.engine.max_new_tokens if max_new_tokens is None
                   else min(max_new_tokens, self.engine.max_new_tokens))
            n_total = PG.pages_needed(
                min(len(tokens) + own, self.max_len), self.page_size)
            # maximal prefix sharing still leaves a non-empty suffix, so at
            # best (plen-1)//page_size pages come from donors — below that
            # the pool can never cover the request, even empty
            max_shared = ((len(tokens) - 1) // self.page_size
                          if self.prefix_sharing and not extras else 0)
            if n_total - max_shared > self.pool_pages:
                raise RequestRejected(
                    f"request needs {n_total - max_shared} fresh pages "
                    f"worst-case but the pool has only {self.pool_pages}")
        if self.engine.cfg.family == "encdec":
            extras = self._pad_encdec_extras(extras)
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, tokens, max_new_tokens, arrival, extras, sampling,
                      priority, deadline, ttft_deadline)
        self.req_times[rid] = {"submitted": time.perf_counter()}
        self.obs.request_begin(rid, prompt_len=len(tokens),
                               arrival=float(arrival))
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self._shed(req)                 # bounded queue: overload -> shed
            return rid
        self._live_req[rid] = req
        self.queue.append(req)
        return rid

    def cancel(self, rid: int) -> bool:
        """Cancel a live request wherever it is in its lifecycle; returns
        True when it was cancelled, False when it had already finished (its
        result stands) or was never submitted.

        A queued / preempted / chunk-prefilling request is dropped host-side
        (lane + page reservations released); a RESIDENT request retires
        mid-flight through the same trash-page path harvest uses — its
        partial output is recorded with ``finish_reason="cancelled"``.  The
        overlap stash is flushed first so an in-flight round that actually
        finished the request wins over the cancel."""
        if rid in self.results:
            return False
        if any(r.rid == rid for r in self.queue):
            self.queue = collections.deque(
                r for r in self.queue if r.rid != rid)
            self._finish_cancel(rid, np.zeros((0,), np.int32), 0)
            return True
        for i, ps in enumerate(self._preempted):
            if ps.req.rid == rid:
                del self._preempted[i]
                n = int(ps.row["ngen"][0])
                self._finish_cancel(rid, ps.row["out"][0, :n].copy(), n)
                return True
        for i, part in enumerate(self._partials):
            if part.req.rid == rid:
                del self._partials[i]
                lane = part.lane
                self.lane_rid[lane] = -1
                self._lane_pending[lane] = False
                if part.plan is not None:
                    freed = [pid for pid in (part.plan.shared
                                             + part.plan.swapped
                                             + part.plan.new)
                             if self.allocator.release(pid)]
                    if freed:
                        self._spill_pages(freed)
                self._finish_cancel(rid, np.zeros((0,), np.int32), 0)
                return True
        if (self.lane_rid == rid).any():
            self._flush_stash()
            if rid in self.results:         # finished in the flushed round
                return False
            lanes = np.flatnonzero(self.lane_rid == rid)
            if lanes.size == 0:
                return False
            self.stats["cancelled"] += 1
            self.obs.request_event(rid, "cancelled")
            self._retire_lane(int(lanes[0]), FinishReason.CANCELLED)
            return True
        return False

    def _finish_cancel(self, rid: int, tokens, n: int):
        self.stats["cancelled"] += 1
        self.obs.request_event(rid, "cancelled")
        self._record_result(rid, tokens, n, FinishReason.CANCELLED)

    def _shed(self, req: Request):
        """Refuse a request the system cannot serve (full queue or an
        infeasible deadline): record an immediate empty ``shed`` result so
        the caller learns NOW instead of after a futile wait."""
        self.stats["shed"] += 1
        self.obs.request_event(req.rid, "shed")
        self._record_result(req.rid, np.zeros((0,), np.int32), 0,
                            FinishReason.SHED)

    def _record_result(self, rid: int, tokens, n: int,
                       reason: "FinishReason"):
        """Single exit point for every non-harvest finish (cancel, deadline,
        shed, drain): records the typed result, closes the request's trace
        track and drops it from the live set."""
        self.results[rid] = {"tokens": np.asarray(tokens, np.int32),
                             "n_generated": int(n),
                             "finished_at": self.now,
                             "finish_reason": reason}
        self.req_times.setdefault(rid, {})["finished"] = time.perf_counter()
        self._live_req.pop(rid, None)
        self.obs.request_end(rid, n_generated=int(n), finished_at=self.now,
                             reason=reason.value)

    def _pad_encdec_extras(self, extras: Optional[dict]) -> dict:
        """Zero-pad a request's encoder memory to the scheduler-wide
        ``src_len`` so every admission sub-batch stacks homogeneously; the
        true length rides along as ``src_lens`` (the attention predicate)."""
        if not extras or "src_emb" not in extras:
            raise ValueError("encdec requests need extras={'src_emb': "
                             "(S_src, d_model) encoder input embeddings}")
        emb = np.asarray(extras["src_emb"])
        if emb.ndim != 2:
            raise ValueError(f"src_emb must be 2-D, got shape {emb.shape}")
        if emb.shape[0] > self.src_len:
            raise ValueError(f"src_emb length {emb.shape[0]} exceeds "
                             f"src_len={self.src_len}")
        sl = int(extras.get("src_lens", emb.shape[0]))
        pad = np.zeros((self.src_len, emb.shape[1]), emb.dtype)
        pad[:emb.shape[0]] = emb
        return dict(extras, src_emb=pad, src_lens=np.int32(sl))

    def occupancy(self) -> float:
        return float((self.lane_rid >= 0).sum()) / self.capacity

    def step(self):
        """One scheduling round: compact, advance chunked prefills, admit,
        decode a chunk, harvest.  Chunked prefills advance by at most one
        chunk per round, so resident lanes decode between a long prompt's
        chunks instead of stalling for its whole prefill.  ``fused=True``
        (the default) issues the round's device work as ONE dispatch;
        ``overlap=True`` additionally harvests one round late from
        prefetched handles (a single blocking sync per round)."""
        self._round_admitted = []
        if self.fused:
            return self._step_fused()
        with self.obs.span("round", round=self.stats["steps"]):
            self._maybe_compact()
            self._sweep_deadlines()
            self._maybe_preempt()
            self._try_resume()
            self._advance_partials()
            self._admit()
            self._reshard()
            occupied = self.lane_rid >= 0
            occ = float(occupied.sum()) / self.capacity
            self.stats["occupancy_trace"].append(occ)
            self.obs.counter("occupancy", occ)
            if self.page_size is not None:
                pocc = self.allocator.live_pages / self.pool_pages
                self.stats["page_occupancy_trace"].append(pocc)
                self.obs.counter("pool_occupancy", pocc)
            if occupied.any():
                eng = self.engine
                gen_before = int(self._block_on(self.n_gen.sum(),
                                                "gen_before"))
                self.stats["dispatches"] += 1
                with self.obs.span("burst", xla=True, chunk=self.chunk):
                    (self.cache, self.out_buf, self.tok, self.p,
                     self.n_gen, self.sstate, steps) = eng._decode_chunk(
                        eng.params, self.cache, self.out_buf, self.tok,
                        self.p, self.n_gen, self.budget, self.sstate,
                        n_steps=self.chunk,
                        stochastic=bool(self._lane_stoch.any()))
                # the jitted loop exits early once every lane retires, and
                # lanes die mid-chunk: charge what actually ran (each active
                # lane-step commits exactly one token, so the n_gen delta is
                # exact)
                steps = int(self._block_on(steps, "steps"))
                self.stats["decode_steps"] += steps
                self.stats["lane_steps"] += steps * self.capacity
                self.stats["active_lane_steps"] += int(
                    self._block_on(self.n_gen.sum(), "gen_after")) - gen_before
                # the clock is in decode-step units: advance by what ran
                self.now += steps
            else:
                self._idle_tick()
            self.stats["steps"] += 1
            self._harvest()

    def _step_fused(self):
        """One round through the fused step program: all host work is
        planning/bookkeeping, all device work is one dispatch.  Non-overlap
        mode syncs on this round's results (same observable order as the
        legacy loop); overlap mode stashes the handles and harvests the
        PREVIOUS round instead."""
        eng = self.engine
        obs = self.obs
        with obs.span("round", round=self.stats["steps"]):
            self._maybe_compact()
            self._sweep_deadlines()
            self._maybe_preempt()
            self._try_resume()
            self._reshard()
            with obs.span("plan"):
                part_steps = self._plan_partial_steps()
                plan = self._plan_admission()
            occupied = self.lane_rid >= 0
            occ = float(occupied.sum()) / self.capacity
            self.stats["occupancy_trace"].append(occ)
            obs.counter("occupancy", occ)
            if self.page_size is not None:
                pocc = self.allocator.live_pages / self.pool_pages
                self.stats["page_occupancy_trace"].append(pocc)
                obs.counter("pool_occupancy", pocc)
            self.stats["steps"] += 1
            if plan is None and not part_steps and not occupied.any():
                self._flush_stash()             # can only be a no-op stash
                self._idle_tick()
                return
            self.stats["dispatches"] += 1
            if plan is None and not part_steps:
                width = self._burst_width()
                with obs.span("dispatch", xla=True, kind="decode",
                              width=width or self.capacity):
                    obs.event("burst", chunk=self.chunk,
                              width=width or self.capacity)
                    (self.cache, self.out_buf, self.tok, self.p, self.n_gen,
                     self.sstate, steps_h) = eng._decode_chunk_serve(
                        eng.params, self.cache, self.out_buf, self.tok,
                        self.p, self.n_gen, self.budget, self.sstate,
                        n_steps=self.chunk,
                        stochastic=bool(self._lane_stoch.any()), width=width)
            else:
                with obs.span("admit", n=plan.n if plan else 0,
                              parts=len(part_steps)):
                    admit = self._assemble_admit(plan)
                    parts, part_final, part_stoch = self._assemble_parts(
                        part_steps)
                admit_stoch = bool(plan is not None and any(
                    self._is_stochastic(s) for s in plan.specs))
                # _lane_stoch / width read AFTER the admit/part assembly
                # committed this round's splices — a just-admitted stochastic
                # lane must get a stochastic decode burst, and a lane spliced
                # in this round must be inside the burst bucket (same
                # ordering as the unfused loop)
                stoch = bool(self._lane_stoch.any())
                width = self._burst_width()
                with obs.span("dispatch", xla=True, kind="fused",
                              width=width or self.capacity):
                    obs.event("burst", chunk=self.chunk,
                              width=width or self.capacity)
                    (self.cache, self.out_buf, self.tok, self.p, self.n_gen,
                     self.budget, self.sstate, steps_h,
                     parts_out) = eng._fused_step(
                        eng.params, self.cache, self.out_buf, self.tok,
                        self.p, self.n_gen, self.budget, self.sstate, admit,
                        parts, n_steps=self.chunk, stochastic=stoch,
                        admit_stoch=admit_stoch, part_final=part_final,
                        part_stoch=part_stoch, max_len=self.max_len,
                        width=width)
                nonfinal = [s.part for s in part_steps if not s.final]
                for part, new_cache in zip(nonfinal, parts_out):
                    part.sub_cache = new_cache
            if self.overlap:
                self._push_stash(steps_h, width)
            else:
                steps = int(self._block_on(steps_h, "steps"))
                self.stats["decode_steps"] += steps
                self.stats["lane_steps"] += steps * (width or self.capacity)
                ngen = self._block_on(self.n_gen, "n_gen")
                base = self._host_ngen.copy()
                base[self._round_admitted] = 1
                self.stats["active_lane_steps"] += int(ngen.sum() - base.sum())
                self._host_ngen = ngen.astype(np.int64)
                self.now += steps
                self._harvest()

    def _burst_width(self):
        """Pow2 lane bucket the fused decode burst may narrow to, or None for
        full width.  Compaction packs live lanes low and whole-prefill
        admissions fill low free lanes first, so the highest occupied
        non-pending lane bounds every lane the burst can commit to; in
        overlap mode the host view lags one harvest and is a SUPERSET of the
        live lanes (conservative).  Only lane-independent families qualify —
        dropping (dead) lanes under MoE changes expert-capacity overflow."""
        if not self._lane_independent:
            return None
        cand = np.flatnonzero((self.lane_rid >= 0) & ~self._lane_pending)
        if cand.size == 0:
            return None
        w = _next_pow2(int(cand[-1]) + 1)
        return w if w < self.capacity else None

    def _idle_tick(self):
        """No lane occupied and nothing admissible: fast-forward the
        decode-step clock straight to the next arrival instead of spinning
        chunk-sized idle rounds (the scalar idle tail of the host loop)."""
        nxt = min((r.arrival for r in self.queue), default=None)
        if nxt is not None and nxt > self.now:
            self.now = float(nxt)
        else:
            self.now += self.chunk
        self.obs.event("idle", now=self.now)

    # ------------------------------------------------------------------
    # async overlap: one-round-delayed harvest from prefetched handles
    # ------------------------------------------------------------------

    def _push_stash(self, steps_h, width=None):
        """Prefetch this round's result handles to the host, harvest the
        PREVIOUS round, then snapshot the post-harvest lane view the new
        stash must be interpreted under (lanes freed just now must not be
        double-harvested next round)."""
        for a in (self.p, self.out_buf, self.n_gen, steps_h):
            a.copy_to_host_async()
        prev = self._stash
        self._stash = {"p": self.p, "out": self.out_buf, "ngen": self.n_gen,
                       "steps": steps_h, "width": width,
                       "admitted": list(self._round_admitted)}
        if prev is not None:
            self._harvest_stash(prev)
        self._stash["lane_rid"] = self.lane_rid.copy()
        self._stash["pending"] = self._lane_pending.copy()

    def _flush_stash(self):
        if self._stash is not None:
            st, self._stash = self._stash, None
            self._harvest_stash(st)

    def _harvest_stash(self, st):
        """The round's SINGLE blocking sync: materialize the prefetched
        handles, account the decode burst, and harvest finished lanes under
        the lane view the stash was created with."""
        with self.obs.span("harvest", delayed=True):
            p, out, ngen, steps_a = self._block_on(
                (st["p"], st["out"], st["ngen"], st["steps"]), "harvest")
            steps = int(steps_a)
            self.stats["decode_steps"] += steps
            self.stats["lane_steps"] += steps * (st.get("width")
                                                 or self.capacity)
            base = self._host_ngen.copy()
            base[st["admitted"]] = 1
            self.stats["active_lane_steps"] += int(ngen.sum() - base.sum())
            self._host_ngen = ngen.astype(np.int64)
            for lane, v in st.get("resumed_fix", {}).items():
                self._host_ngen[lane] = v
            self.now += steps
            finished = np.flatnonzero((st["lane_rid"] >= 0) & ~p
                                      & ~st["pending"])
            if finished.size == 0:
                return
            t = time.perf_counter()
            freed: list = []
            for lane in finished:
                lane = int(lane)
                rid = int(st["lane_rid"][lane])
                n = int(ngen[lane])
                reason = (FinishReason.PREEMPTED_RESUMED
                          if self._rid_preempts.get(rid)
                          else FinishReason.DONE)
                self.results[rid] = {"tokens": out[lane, :n].copy(),
                                     "n_generated": n,
                                     "finished_at": self.now,
                                     "finish_reason": reason}
                self.req_times[rid]["finished"] = t
                self._live_req.pop(rid, None)
                self.obs.request_end(rid, n_generated=n,
                                     finished_at=self.now,
                                     reason=reason.value)
                self.lane_rid[lane] = -1
                self._lane_stoch[lane] = False
                if self.page_size is not None:
                    for pid in self.lane_pages.pop(lane):
                        if self.allocator.release(pid):
                            freed.append(pid)
            if self.page_size is not None:
                if freed:
                    self._spill_pages(freed)
                self.cache["page_table"] = self.cache["page_table"].at[
                    jnp.asarray(finished, jnp.int32)].set(self.trash_page)

    def run(self) -> dict[int, dict]:
        """Drain the queue and all live lanes; returns ``{rid: result}``.

        Calls ``step()`` (one scheduling round: plan, one fused dispatch,
        harvest the previous round) until no request is queued or resident,
        then flushes the overlap stash.  Each result carries ``tokens`` (the
        generated ids, stop token excluded) and ``n_generated``; per-request
        timing lands in ``req_times`` and aggregate counters in ``stats``.
        ``run`` is resumable: more ``submit``s after it returns and a second
        ``run()`` continue on the same lanes/pages/prefix state — with the
        host-swap tier on, later calls hit prefixes earlier calls retired.

        ``run`` never strands state: a ``KeyboardInterrupt`` drains the loop
        (stash flushed, every live request recorded with partial output and
        ``finish_reason="cancelled"``, allocator leak-free) and RETURNS the
        partial results; any other exception drains the same way and then
        re-raises — the scheduler is consistent either way.
        """
        try:
            while (self.queue or self._preempted
                   or (self.lane_rid >= 0).any()):
                self.step()
        except KeyboardInterrupt:
            self._abort_drain()
        except BaseException:
            self._abort_drain()
            raise
        finally:
            self._flush_stash()
        return self.results

    def _abort_drain(self):
        """Tear the serve loop down to a consistent idle state: flush the
        in-flight stash, record partial ``cancelled`` results for every live
        request (resident, chunk-prefilling, preempted or queued) and free
        their lanes/pages.  Asserts the allocator ends leak-free — resident
        == 0 after a full drain, so ``live_pages`` must be 0 too."""
        try:
            self._flush_stash()
        except Exception:           # a broken device must not block drain
            self._stash = None
        for part in list(self._partials):
            self.cancel(part.req.rid)
        for lane in np.flatnonzero(self.lane_rid >= 0):
            rid = int(self.lane_rid[int(lane)])
            self.stats["cancelled"] += 1
            self.obs.request_event(rid, "cancelled")
            self._retire_lane(int(lane), FinishReason.CANCELLED)
        for ps in list(self._preempted):
            self.cancel(ps.req.rid)
        for req in list(self.queue):
            self.cancel(req.rid)
        if self.page_size is not None:
            assert self.allocator.live_pages == 0, (
                f"page leak after drain: {self.allocator.live_pages} "
                "pages still held with no resident lane")

    # ------------------------------------------------------------------
    # request-lifecycle control plane: deadlines, preemption, resume
    # ------------------------------------------------------------------

    def _retire_lane(self, lane: int, reason: "FinishReason"):
        """Retire a RESIDENT lane mid-flight (cancel/deadline/drain): read
        its partial output, record the typed result and free the lane + its
        page chain through the same trash-page path harvest uses.  The
        caller must have flushed the stash first — retiring under an
        unharvested snapshot would double-harvest the lane."""
        rid = int(self.lane_rid[lane])
        out, ngen = self._block_on((self.out_buf[lane], self.n_gen[lane]),
                                   "retire")
        n = int(ngen)
        self._record_result(rid, out[:n].copy(), n, reason)
        self.p = self.p.at[lane].set(False)
        self.lane_rid[lane] = -1
        self._lane_stoch[lane] = False
        # keep the host n_gen mirror at the DEVICE value (stale rows of free
        # lanes are part of the active_lane_steps accounting contract)
        self._host_ngen[lane] = n
        if self.page_size is not None:
            freed = [pid for pid in self.lane_pages.pop(lane, [])
                     if self.allocator.release(pid)]
            if freed:
                self._spill_pages(freed)
            self.cache["page_table"] = self.cache["page_table"].at[
                lane].set(self.trash_page)
        self._reshard()

    def _sweep_deadlines(self):
        """Retire resident lanes whose finish deadline has passed (partial
        output, ``finish_reason="deadline"``).  The cheap pre-check keeps
        the overlap loop's one-sync-per-round property: the stash is only
        flushed when some lane is actually over deadline."""
        over = [int(l) for l in np.flatnonzero(self.lane_rid >= 0)
                if not self._lane_pending[int(l)]
                and (r := self._live_req.get(int(self.lane_rid[int(l)])))
                is not None and r.deadline is not None
                and self.now > r.deadline]
        if not over:
            return
        self._flush_stash()
        for lane in over:
            rid = int(self.lane_rid[lane])
            req = self._live_req.get(rid)
            if rid < 0 or req is None:      # finished in the flushed round
                continue
            self.stats["deadline_misses"] += 1
            self.obs.request_event(rid, "deadline")
            self._retire_lane(lane, FinishReason.DEADLINE)

    def _est_ttft(self) -> float:
        """Estimated queue-wait-to-first-token in decode steps: the p50 of
        the ``ttft_steps`` histogram (0 before any admission — optimistic
        until the system has seen its own latency)."""
        h = self._ttft_hist
        return float(h.percentile(50)) if h.count else 0.0

    def _shed_infeasible(self, req: Request) -> bool:
        """True when the request can no longer meet its deadlines: its
        predicted first-token time — now, or its arrival plus the observed
        p50 queue wait, whichever is later — is past ``ttft_deadline`` or
        ``deadline``.  Pure estimate, no device touch."""
        if req.ttft_deadline is None and req.deadline is None:
            return False
        first = max(self.now, req.arrival + self._est_ttft())
        if req.ttft_deadline is not None and first > req.ttft_deadline:
            return True
        return req.deadline is not None and first > req.deadline

    def _fresh_pages_needed(self, req: Request) -> int:
        """Pages ``_plan_pages`` would freshly allocate for this request —
        the same lookup, side-effect-free (no refcounts, no stats).  Drives
        the preemption trigger: preempt only when the top-priority waiter
        cannot get this many pages from the free list."""
        if self.page_size is None:
            return 0
        ps = self.page_size
        plen = len(req.tokens)
        shared: list = []
        if self.prefix_sharing and not req.extras:
            shared = self.prefix_index.lookup(req.tokens, ps)
            while shared and len(shared) * ps >= plen:
                shared.pop()
        budget = self._budget_for(req, plen)
        return (PG.pages_needed(min(plen + budget, self.max_len), ps)
                - len(shared))

    def _maybe_preempt(self):
        """Priority preemption trigger, run once per round before planning:
        while the highest-priority waiting request (queued-and-due or
        already preempted) is starved — no free lane, or fewer free pages
        than it needs — evict the lowest-priority resident lane whose
        priority is STRICTLY below it.  Equal priorities never preempt each
        other, so all-default-priority traffic behaves exactly as before."""
        for _ in range(self.capacity):
            best_q = None
            for r in self.queue:
                if self._due(r) and (best_q is None
                                     or r.priority > best_q.priority):
                    best_q = r
            top_pri = None if best_q is None else best_q.priority
            top_need = None
            for ps in self._preempted:
                if top_pri is None or ps.req.priority > top_pri:
                    top_pri, top_need = ps.req.priority, ps.n_pages
            if top_pri is None:
                return
            # victim check BEFORE the page-need lookup: with all-equal
            # priorities (the common case) no lane can ever be evicted, and
            # the per-round prefix-index walk in _fresh_pages_needed would
            # be pure overhead on the admission hot path
            victim = self._victim_lane(top_pri)
            if victim is None:
                return
            if top_need is None:
                top_need = self._fresh_pages_needed(best_q)
            starved = len(self._free_lanes()) == 0 or (
                self.page_size is not None
                and top_need > self.allocator.free_pages)
            if not starved:
                return
            self._preempt_lane(victim)

    def _victim_lane(self, above: int) -> Optional[int]:
        """Lowest-priority resident non-pending lane strictly below
        ``above`` (ties: lowest lane index — deterministic), or None."""
        best = None
        for lane in np.flatnonzero(self.lane_rid >= 0):
            lane = int(lane)
            if self._lane_pending[lane]:
                continue
            req = self._live_req.get(int(self.lane_rid[lane]))
            if req is None or req.priority >= above:
                continue
            if best is None or req.priority < best[0]:
                best = (req.priority, lane)
        return None if best is None else best[1]

    def _preempt_lane(self, lane: int):
        """Spill a resident lane's COMPLETE state to host and free it: page
        blocks through the host-swap gather path, dense carries + decode
        rows + sampler row through ``_spill_lane`` — one blocking sync for
        all of it.  The request re-queues as a ``PreemptedState``; resuming
        splices everything back bit-exactly."""
        self._flush_stash()
        rid = int(self.lane_rid[lane])
        if rid < 0:                         # finished in the flushed round
            return
        req = self._live_req[rid]
        eng = self.engine
        lane_idx = np.asarray([lane], np.int32)
        stoch = bool(self._lane_stoch[lane])
        with self.obs.span("preempt", rid=rid, lane=lane):
            self.stats["dispatches"] += 1
            dense_h, row_h, srow_h = eng._spill_lane(
                self.cache, self.out_buf, self.tok, self.n_gen, self.budget,
                self.sstate, lane_idx)
            blocks_h = None
            pages: list = []
            if self.page_size is not None:
                pages = self.lane_pages.get(lane, [])
                if pages:
                    kpad = _next_pow2(len(pages))
                    pids = np.full((kpad,), self.trash_page, np.int32)
                    pids[:len(pages)] = pages
                    self.stats["dispatches"] += 1
                    blocks_h = eng._gather_blocks(self.cache,
                                                  jnp.asarray(pids))
            # np.array (copy=True) leaves: PreemptedState must own its bytes
            # — on donating backends the device buffers are recycled next
            # dispatch
            dense, row, srow, blocks = jax.tree_util.tree_map(
                np.array, self._block_on((dense_h, row_h, srow_h, blocks_h),
                                         "preempt"))
            if blocks is not None:
                blocks = {k: b[:len(pages)] for k, b in blocks.items()}
            if self.page_size is not None:
                freed = [pid for pid in self.lane_pages.pop(lane, [])
                         if self.allocator.release(pid)]
                if freed:
                    self._spill_pages(freed)
                self.cache["page_table"] = self.cache["page_table"].at[
                    lane].set(self.trash_page)
            self.p = self.p.at[lane].set(False)
            self.lane_rid[lane] = -1
            self._lane_stoch[lane] = False
            self._host_ngen[lane] = int(row["ngen"][0])
            self._preempted.append(PreemptedState(
                req=req, dense=dense, blocks=blocks, n_pages=len(pages),
                row=row, srow=srow, stoch=stoch, order=self._preempt_seq))
            self._preempt_seq += 1
            self.stats["preemptions"] += 1
            self._rid_preempts[rid] = self._rid_preempts.get(rid, 0) + 1
            self.obs.request_event(rid, "preempted", lane=lane,
                                   n_gen=int(row["ngen"][0]))
            self._reshard()

    def _try_resume(self):
        """Re-admit preempted requests (highest priority first, FIFO within
        a level) as soon as a lane and their full page-chain allocation are
        available.  Resume takes the LOWEST free lane — the same fill order
        admission uses, so burst narrowing stays valid."""
        if not self._preempted:
            return
        # never resume BELOW a due queued request's priority: preemption
        # just freed resources for it, and resuming the victim right back
        # would thrash (preempt -> resume -> preempt) until the pool grows
        top_queued = max((r.priority for r in self.queue if self._due(r)),
                         default=None)
        still: list[PreemptedState] = []
        for ps in sorted(self._preempted,
                         key=lambda s: (-s.req.priority, s.order)):
            if top_queued is not None and ps.req.priority < top_queued:
                still.append(ps)
                continue
            free = self._free_lanes()
            if len(free) == 0:
                still.append(ps)
                continue
            new = None
            if self.page_size is not None and ps.n_pages:
                new = self.allocator.alloc(ps.n_pages)
                if new is None:
                    self.stats["page_waits"] += 1
                    still.append(ps)
                    continue
            self._resume_state(ps, int(free[0]), new)
        self._preempted = still

    def _resume_state(self, ps: PreemptedState, lane: int, new_pages):
        """Splice a ``PreemptedState`` back into ``lane``: scatter its page
        blocks into freshly allocated pages (same batched write as swap-in),
        rebuild the page-table row (tail-padded with the last page, the
        clamped-write containment rule), then restore dense carries + decode
        rows + sampler row via ``_resume_lane``.  The resumed chain is NOT
        re-registered in the prefix index — its pages are private now; a
        later prompt sharing this prefix pays a cold prefill (correct,
        merely unshared)."""
        eng = self.engine
        rid = ps.req.rid
        lane_idx = np.asarray([lane], np.int32)
        table_row = None
        with self.obs.span("resume", rid=rid, lane=lane):
            if self.page_size is not None and ps.n_pages:
                kpad = _next_pow2(ps.n_pages)
                pids = np.full((kpad,), self.trash_page, np.int32)
                pids[:ps.n_pages] = new_pages
                blocks = {}
                for pk, b in ps.blocks.items():
                    pad = np.zeros((kpad - ps.n_pages,) + b.shape[1:],
                                   b.dtype)
                    blocks[pk] = np.concatenate([b, pad]) if kpad > \
                        ps.n_pages else b
                self.stats["dispatches"] += 1
                self.cache = eng._scatter_blocks(self.cache,
                                                 jnp.asarray(pids), blocks)
                tab = np.full((self.n_pages,), new_pages[-1], np.int32)
                tab[:ps.n_pages] = new_pages
                table_row = tab
                self.lane_pages[lane] = list(new_pages)
                self.stats["resume_page_ins"] += ps.n_pages
            self.stats["dispatches"] += 1
            (self.cache, self.out_buf, self.tok, self.p, self.n_gen,
             self.budget, self.sstate) = eng._resume_lane(
                self.cache, self.out_buf, self.tok, self.p, self.n_gen,
                self.budget, self.sstate, lane_idx, ps.dense, ps.row,
                ps.srow, table_row)
            self._reshard()
            self.lane_rid[lane] = rid
            self._lane_stoch[lane] = ps.stoch
            self._host_ngen[lane] = int(ps.row["ngen"][0])
            if self._stash is not None:
                # the in-flight round's device n_gen row for this lane may
                # belong to a PREVIOUS occupant: pin the post-harvest mirror
                # back to the resumed value when that stash lands
                self._stash.setdefault("resumed_fix", {})[lane] = int(
                    ps.row["ngen"][0])
            self.obs.request_event(rid, "resumed", lane=lane)

    # ------------------------------------------------------------------
    # lane lifecycle
    # ------------------------------------------------------------------

    def _free_lanes(self):
        return np.flatnonzero(self.lane_rid < 0)

    def _due(self, req: Request) -> bool:
        return req.arrival <= self.now

    def _budget_for(self, req: Request, plen: int) -> int:
        """The request's decode-token budget: its own cap, clamped to the
        engine burst budget and the lane's remaining cache extent.  THE
        single definition — paged planning, whole-prefill admission and
        chunked-prefill reservation must all agree or chunked==whole
        bit-identity breaks."""
        own = (self.engine.max_new_tokens if req.max_new_tokens is None
               else req.max_new_tokens)
        return min(own, self.engine.max_new_tokens, self.max_len - plen)

    def _plan_pages(self, req: Request) -> Optional[_PagePlan]:
        """Reserve pages for one request: longest resident prompt prefix is
        SHARED (refcount bump, no prefill), then — with the eviction tier
        enabled — the chain is EXTENDED through host-swapped pages (fresh
        allocations whose content pages in from the host store), and the
        rest is freshly allocated for suffix prefill.  Returns None — and
        touches nothing — when the pool can't cover it: admission is gated
        on page availability, not lane count."""
        ps = self.page_size
        plen = len(req.tokens)
        budget = self._budget_for(req, plen)
        shared: list = []
        swap_entries: list = []
        if self.prefix_sharing and not req.extras:
            shared = self.prefix_index.lookup(req.tokens, ps)
            # the suffix prefill must be non-empty (the last prompt token's
            # logits seed decode), so never share the whole prompt
            while shared and len(shared) * ps >= plen:
                shared.pop()
            if self.host_swap is not None:
                # extend the resident chain through the host store (same
                # non-empty-suffix guard as above), VERIFYING each entry's
                # checksum on the way — a corrupt hit drops out of the store
                # and degrades the rest of the chain to cold prefill, never
                # to wrong tokens
                cf0 = self.host_swap.checksum_failures
                j = len(shared)
                while (j + 1) * ps < plen:
                    entry = self.host_swap.get(
                        req.tokens[:(j + 1) * ps].tobytes())
                    if entry is None:
                        break
                    swap_entries.append(entry)
                    j += 1
                cf = self.host_swap.checksum_failures - cf0
                if cf:
                    self.stats["swap_checksum_failures"] += cf
        n_total = PG.pages_needed(min(plen + budget, self.max_len), ps)
        new = self.allocator.alloc(n_total - len(shared))
        if new is None:
            self.stats["page_waits"] += 1
            return None
        for pid in shared:
            self.allocator.retain(pid)
        swapped, new = new[:len(swap_entries)], new[len(swap_entries):]
        if swapped:
            self._page_in(swapped, swap_entries)
            self.stats["session_hits"] += 1
            self.stats["session_hit_tokens"] += len(swapped) * ps
        if shared:
            self.stats["prefix_hits"] += 1
            self.stats["prefix_hit_tokens"] += len(shared) * ps
        return _PagePlan(shared=shared, swapped=swapped, new=new,
                         budget=budget, plen=plen,
                         pos0=(len(shared) + len(swapped)) * ps)

    def _unplan_pages(self, plan: _PagePlan):
        """Roll back a reservation for a candidate that didn't fit the
        admission group after all (releases never free a donor's pages —
        the donor still holds its own references).  Paged-in swap pages
        release to the free list; their content stays in the host store
        (content-addressed, immutable), so a re-plan just pages them in
        again."""
        for pid in plan.new + plan.swapped + plan.shared:
            self.allocator.release(pid)
        if plan.shared:
            self.stats["prefix_hits"] -= 1
            self.stats["prefix_hit_tokens"] -= len(plan.shared) * self.page_size
        if plan.swapped:
            self.stats["session_hits"] -= 1
            self.stats["session_hit_tokens"] -= (len(plan.swapped)
                                                 * self.page_size)

    def _plan_admission(self) -> Optional[_AdmitPlan]:
        """Scan the queue and plan this round's admission sub-batch — pure
        host work (no device touch beyond allocator/prefix bookkeeping).

        The whole queue is scanned (a not-yet-due request must not block due
        ones behind it); FIFO order is preserved among the due.  One prefill
        sub-batch must stack homogeneously, so only requests with the same
        extras keys are admitted together — the rest wait for the next round.
        Under paging each candidate must also fit the page pool
        (``_plan_pages``); prefix-hit rows prefill only their suffix.

        With ``prefill_chunk`` set, a request whose (suffix) prompt exceeds
        the chunk becomes a chunked-prefill PARTIAL instead: it claims a lane
        (from the tail of the free list, so whole-prefill admissions keep the
        head) and its pages, then prefills chunk-by-chunk across rounds.
        """
        free = self._free_lanes()
        batch_reqs: list[Request] = []
        plans: list[_PagePlan] = []
        queue = list(self.queue)
        keep = [True] * len(queue)          # stays queued for a later round
        extras_keys = None
        n_claimed = 0                       # lanes claimed by new partials
        suffix_max = pos0_max = 0
        # higher priority scans first; the sort is stable, so FIFO holds
        # within a level and all-default-priority traffic scans in exactly
        # the old submission order
        for qi in sorted(range(len(queue)), key=lambda i: -queue[i].priority):
            req = queue[qi]
            if not self._due(req):
                continue
            if self._shed_infeasible(req):  # can't meet its deadline: shed
                keep[qi] = False
                self._shed(req)
                continue
            if len(batch_reqs) + n_claimed >= len(free):
                continue
            keys = frozenset(req.extras) if req.extras else frozenset()
            # extras ride chunked prefill only when they are per-request
            # constants the FIRST chunk consumes whole (encdec's encoder
            # memory); token-aligned extras would need per-chunk slicing
            chunkable = self.prefill_chunk is not None and (
                not req.extras or self.engine.cfg.family == "encdec")
            if extras_keys is not None and keys != extras_keys:
                continue
            if self.page_size is None and chunkable \
                    and len(req.tokens) > self.prefill_chunk:
                self._start_partial(req, None, free[len(free) - 1 - n_claimed])
                n_claimed += 1
                keep[qi] = False
                continue
            if self.page_size is not None:
                plan = self._plan_pages(req)
                if plan is None:                    # pool exhausted: wait
                    continue
                if chunkable and plan.plen - plan.pos0 > self.prefill_chunk:
                    self._start_partial(req, plan,
                                        free[len(free) - 1 - n_claimed])
                    n_claimed += 1
                    keep[qi] = False
                    continue
                # group-fit guard: the prefill writes ONE padded suffix block
                # per row at its pos0, and dynamic_update_slice CLAMPS the
                # start when pos0 + plen_pad > max_len — which would shift a
                # prefix-shared row's K/V over its seeded prefix.  Only
                # co-admit candidates whose shared padded width still fits
                # every row's offset; a lone candidate always fits (its
                # suffix <= max_len - pos0 by construction).
                s_max = max(suffix_max, plan.plen - plan.pos0)
                p_max = max(pos0_max, plan.pos0)
                if min(_next_pow2(s_max), self.max_len - p_max) < s_max:
                    self._unplan_pages(plan)        # wait for a better group
                    continue
                suffix_max, pos0_max = s_max, p_max
                plans.append(plan)
            batch_reqs.append(req)
            keep[qi] = False
            if extras_keys is None:
                extras_keys = keys
        self.queue = collections.deque(
            q for i, q in enumerate(queue) if keep[i])
        if not batch_reqs:
            return None
        n = len(batch_reqs)
        lanes = free[:n]
        pos0 = np.array([pl.pos0 for pl in plans] or [0] * n, np.int32)
        # bucket the prefill shape (rows to a power of two, columns to a
        # power of two capped at max_len) so a ragged trace compiles a
        # BOUNDED set of prefill programs instead of one per (n, plen) pair
        n_pad = min(_next_pow2(n), self.capacity)
        plen = max(len(r.tokens) - int(pos0[i])
                   for i, r in enumerate(batch_reqs))
        # cap the bucket so pos0 + plen_pad <= max_len for every admitted row
        # (the group-fit guard above guarantees plen still fits the cap)
        plen_pad = min(_next_pow2(plen), self.max_len - int(pos0.max()))
        toks = np.zeros((n_pad, plen_pad), np.int32)
        lens = np.ones((n_pad,), np.int32)          # dummy rows: 1-token pad
        pos0_pad = np.zeros((n_pad,), np.int32)
        for i, r in enumerate(batch_reqs):
            suffix = r.tokens[pos0[i]:]
            toks[i, :len(suffix)] = suffix
            lens[i] = len(suffix)
            pos0_pad[i] = pos0[i]
        self.stats["prefill_tokens"] += int(lens[:n].sum())
        specs = [self._effective_spec(r) for r in batch_reqs]
        if plans:
            budgets = np.asarray([pl.budget for pl in plans], np.int32)
        else:
            budgets = np.asarray([self._budget_for(r, int(lens[i]))
                                  for i, r in enumerate(batch_reqs)], np.int32)
        t = time.perf_counter()
        for i, r in enumerate(batch_reqs):
            self.req_times[r.rid]["first_token"] = t
            # queue-wait-to-first-token in steps: feeds the shed estimator
            self._ttft_hist.record(self.now - r.arrival)
            pl = plans[i] if plans else None
            self.obs.request_event(
                r.rid, "admitted", lane=int(lanes[i]),
                **({"shared_pages": len(pl.shared),
                    "swapped_pages": len(pl.swapped),
                    "new_pages": len(pl.new)} if pl is not None else {}))
            self.obs.request_event(r.rid, "first_token")
        return _AdmitPlan(reqs=batch_reqs, plans=plans, lanes=lanes, n=n,
                          n_pad=n_pad, toks=toks, lens=lens,
                          pos0_pad=pos0_pad, budgets=budgets, specs=specs)

    def _admit_batch(self, plan: _AdmitPlan) -> dict:
        """Device-ready prefill batch for an admission plan (dummy rows of
        ``src_lens`` pad to 1, not 0 — an all-masked attention row would
        produce NaNs; everything else zero-pads)."""
        # numpy leaves on purpose: the batch crosses a jit boundary right
        # after assembly, so eager jnp conversion here would pay one device
        # dispatch per field per admission round on the serve loop's host path
        batch = {"tokens": plan.toks, "lens": plan.lens}
        if self.page_size is not None:
            batch["pos0"] = plan.pos0_pad
        r0 = plan.reqs[0]
        if r0.extras:
            for k in r0.extras:
                proto = np.asarray(r0.extras[k])
                pad = (np.ones_like(proto) if k == "src_lens"
                       else np.zeros_like(proto))
                batch[k] = np.stack([np.asarray(r.extras[k])
                                     for r in plan.reqs]
                                    + [pad] * (plan.n_pad - plan.n))
        return batch

    def _admit(self):
        """Unfused admission executor: prefill the planned sub-batch as its
        own dispatch and splice it into the recycled lanes (slot_update =
        the in-place `.at[]` scatter)."""
        plan = self._plan_admission()
        if plan is None:
            return
        eng = self.engine
        n, n_pad, lanes = plan.n, plan.n_pad, plan.lanes
        batch = self._admit_batch(plan)
        sub_cache = eng.make_cache(n_pad, self.max_len, batch)
        if self.page_size is not None:
            sub_cache = self._seed_shared_prefix(sub_cache, plan.plans, n_pad)
        self.stats["dispatches"] += 1
        with self.obs.span("admit", xla=True, n=n):
            logits, sub_cache = eng._prefill(eng.params, batch, sub_cache)
        # per-request sampler rows: built from each request's OWN spec/seed
        # (dummy pad rows are greedy with a zero key), first token sampled
        # through the same repro.sample entry point the decode loop uses
        sub_state = S.lane_state(plan.specs, n_pad)
        if any(self._is_stochastic(s) for s in plan.specs):
            first_tok, sub_state = eng._sample(logits, sub_state)
        else:
            # all-greedy admission skips the stochastic pipeline (greedy
            # keys are never read, so leaving them unsplit is inert)
            first_tok = eng._sample(logits)
        first_tok = first_tok[:n]
        if self.page_size is not None:
            self._copy_pages(sub_cache, plan.plans, lanes)
            for req, pl in zip(plan.reqs, plan.plans):
                self._register_prefix(req, pl)
        if n_pad > n:                               # drop the dummy rows
            sub_cache = gather_lanes(eng.cfg, sub_cache,
                                     jnp.arange(n, dtype=jnp.int32))

        # ---- splice the sub-batch into the recycled lanes ----
        lane_idx = jnp.asarray(lanes, jnp.int32)
        self.cache = slot_update(eng.cfg, self.cache, lane_idx, sub_cache)
        self.sstate = S.slot_update(
            self.sstate, lane_idx,
            S.gather_lanes(sub_state, jnp.arange(n, dtype=jnp.int32)))
        budgets = plan.budgets
        self.tok = self.tok.at[lane_idx].set(first_tok)
        self.out_buf = self.out_buf.at[lane_idx].set(0)
        self.out_buf = self.out_buf.at[lane_idx, 0].set(first_tok)
        self.n_gen = self.n_gen.at[lane_idx].set(1)
        self.budget = self.budget.at[lane_idx].set(jnp.asarray(budgets))
        alive = (first_tok != eng.stop_token) & (jnp.asarray(budgets) > 1)
        self.p = self.p.at[lane_idx].set(alive)
        for i, r in enumerate(plan.reqs):
            self.lane_rid[lanes[i]] = r.rid
            self._lane_stoch[lanes[i]] = self._is_stochastic(plan.specs[i])
            self._round_admitted.append(int(lanes[i]))

    def _assemble_admit(self, plan: Optional[_AdmitPlan]) -> Optional[dict]:
        """Turn an admission plan into the fused step's ``admit`` input:
        device arrays only, padded rows aimed at out-of-range lanes (index
        scatters drop them) and padded page copies at the trash page.  Also
        commits the host-side lane bookkeeping the splice implies."""
        if plan is None:
            return None
        lanes = np.full((plan.n_pad,), self.capacity, np.int32)
        lanes[:plan.n] = plan.lanes
        budgets = np.zeros((plan.n_pad,), np.int32)
        budgets[:plan.n] = plan.budgets
        admit = {"batch": self._admit_batch(plan),
                 "lanes": lanes,
                 "budgets": budgets,
                 "sub_state": S.lane_state(plan.specs, plan.n_pad)}
        if self.page_size is not None:
            seed = self._seed_arrays(plan.plans, plan.n_pad)
            if seed is not None:
                admit["seed_tab"], admit["seed_len"] = seed
            rows, cols, dsts, tab_rows = self._page_copy_plan(plan.plans)
            kpad = _next_pow2(len(rows))
            rows_a = np.zeros((kpad,), np.int32)
            rows_a[:len(rows)] = rows
            cols_a = np.zeros((kpad,), np.int32)
            cols_a[:len(cols)] = cols
            dsts_a = np.full((kpad,), self.trash_page, np.int32)
            dsts_a[:len(dsts)] = dsts
            tab_full = np.zeros((plan.n_pad, self.n_pages), np.int32)
            tab_full[:plan.n] = tab_rows
            admit["copy_rows"] = rows_a
            admit["copy_cols"] = cols_a
            admit["copy_dsts"] = dsts_a
            admit["tab_rows"] = tab_full
            for i, pl in enumerate(plan.plans):
                self.lane_pages[int(plan.lanes[i])] = (pl.shared + pl.swapped
                                                       + pl.new)
            for req, pl in zip(plan.reqs, plan.plans):
                self._register_prefix(req, pl)
        for i, r in enumerate(plan.reqs):
            self.lane_rid[plan.lanes[i]] = r.rid
            self._lane_stoch[plan.lanes[i]] = self._is_stochastic(
                plan.specs[i])
            self._round_admitted.append(int(plan.lanes[i]))
        return admit

    def _effective_spec(self, req: Request):
        """The request's own SamplingParams, or the engine-wide default —
        decorrelated per request by folding its rid into the default's key
        (``fold_in`` can never collide with another request's explicit
        ``PRNGKey(seed)``, and it bit-matches the one-shot engine's
        broadcast path when submission order equals lane order)."""
        if req.sampling is not None:
            return req.sampling
        d = self.engine.default_sampling
        if d is None or d.greedy or d.temperature <= 0 or d.fold is not None:
            return d
        return dataclasses.replace(d, fold=req.rid)

    @staticmethod
    def _is_stochastic(spec) -> bool:
        return not (spec is None or spec.greedy or spec.temperature <= 0)

    # ------------------------------------------------------------------
    # chunked prefill (admission interleaved with decode rounds)
    # ------------------------------------------------------------------

    def _start_partial(self, req: Request, plan: Optional[_PagePlan],
                       lane: int):
        """Reserve a lane (and, under paging, the request's full page plan)
        and begin prefilling its prompt in chunks.  The lane is marked
        pending: it keeps decoding architecturally inside the jitted chunk
        (writes land beyond-pos garbage / in the trash page) but is excluded
        from commits, harvest and admission until the final chunk splices."""
        eng = self.engine
        lane = int(lane)
        budget = (plan.budget if plan is not None
                  else self._budget_for(req, len(req.tokens)))
        sub_cache = eng.make_cache(1, self.max_len, src_len=self.src_len)
        seed = (self._seed_arrays([plan], 1)
                if plan is not None and plan.shared else None)
        self.lane_rid[lane] = req.rid
        self._lane_pending[lane] = True
        self._partials.append(_Partial(
            req=req, plan=plan, lane=lane, sub_cache=sub_cache, done=0,
            pos0=plan.pos0 if plan is not None else 0, budget=budget,
            seed=seed))
        self.obs.request_event(req.rid, "prefill_start", lane=lane,
                               suffix=len(req.tokens)
                               - (plan.pos0 if plan is not None else 0))

    def _plan_partial_steps(self) -> list[_PartStep]:
        """Plan at most ONE prefill chunk per pending request — pure host
        work shared by the unfused executor and the fused assembly.  Chunk
        widths bucket to powers of two capped at the row's remaining extent,
        so the `dynamic_update_slice` at pos0+done never clamps (a lone
        row's suffix always fits its cache tail).  Final chunks commit their
        host-side bookkeeping here (prefix registration, pending clear) so
        this round's admission planning already sees the spliced state —
        the same ordering the unfused loop had."""
        if not self._partials:
            return []
        steps: list[_PartStep] = []
        still: list[_Partial] = []
        t = None
        for part in self._partials:
            toks = part.req.tokens
            start = part.pos0 + part.done
            n = min(self.prefill_chunk, len(toks) - start)
            width = min(_next_pow2(n), self.max_len - start)
            buf = np.zeros((1, width), np.int32)
            buf[0, :n] = toks[start:start + n]
            batch = {"tokens": buf,
                     "lens": np.asarray([n], np.int32),
                     "pos0": np.asarray([start], np.int32)}
            seed = None
            if part.done == 0:
                seed, part.seed = part.seed, None
                if part.req.extras:
                    # per-request constant extras (encdec encoder memory)
                    # ride the FIRST chunk only: the encoder runs once and
                    # its cross K/V persists in the accumulating sub-cache
                    for k, v in part.req.extras.items():
                        batch[k] = np.asarray(v)[None]
            self.stats["prefill_tokens"] += n
            self.stats["prefill_chunks"] += 1
            part.done += n
            self.obs.request_event(part.req.rid, "prefill_chunk",
                                   done=part.done)
            final = start + n >= len(toks)
            steps.append(_PartStep(part=part, batch=batch, final=final,
                                   seed=seed))
            if not final:
                still.append(part)
                continue
            spec = self._effective_spec(part.req)
            if part.plan is not None:
                self.lane_pages[part.lane] = (part.plan.shared
                                              + part.plan.swapped
                                              + part.plan.new)
                self._register_prefix(part.req, part.plan)
            self._lane_pending[part.lane] = False
            self._lane_stoch[part.lane] = self._is_stochastic(spec)
            self._round_admitted.append(part.lane)
            t = time.perf_counter() if t is None else t
            self.req_times[part.req.rid]["first_token"] = t
            self._ttft_hist.record(self.now - part.req.arrival)
            self.obs.request_event(part.req.rid, "admitted",
                                   lane=part.lane, chunked=True)
            self.obs.request_event(part.req.rid, "first_token")
        self._partials = still
        return steps

    def _advance_partials(self):
        """Unfused executor: run each planned chunk as its own prefill
        dispatch, splicing those that finish."""
        for s in self._plan_partial_steps():
            batch = {k: jnp.asarray(v) for k, v in s.batch.items()}
            if s.seed is not None:
                s.part.sub_cache = self.engine._seed_pages(
                    self.cache, s.part.sub_cache, s.seed[0], s.seed[1],
                    self.max_len)
            self.stats["dispatches"] += 1
            with self.obs.span("prefill_chunk", xla=True,
                               rid=s.part.req.rid, final=s.final):
                logits, s.part.sub_cache = self.engine._prefill(
                    self.engine.params, batch, s.part.sub_cache)
            if s.final:
                self._splice_partial(s.part, logits)

    def _assemble_parts(self, steps: list[_PartStep]):
        """Turn planned partial chunks into the fused step's ``parts`` input
        (device arrays + static final/stochastic flags).  Final chunks carry
        their splice data: target lane, budget, sampler row and — under
        paging — their page-copy plan."""
        parts, finals, stochs = [], [], []
        for s in steps:
            # numpy leaves (see _admit_batch): one device transfer at the
            # fused jit boundary instead of one eager dispatch per field
            d = {"batch": dict(s.batch), "cache": s.part.sub_cache}
            if s.seed is not None:
                d["seed_tab"], d["seed_len"] = s.seed
            stoch = False
            if s.final:
                spec = self._effective_spec(s.part.req)
                stoch = self._is_stochastic(spec)
                d["sub_state"] = S.lane_state([spec], 1)
                d["lane"] = np.asarray([s.part.lane], np.int32)
                d["budget"] = np.asarray([s.part.budget], np.int32)
                if self.page_size is not None:
                    rows, cols, dsts, tab = self._page_copy_plan(
                        [s.part.plan])
                    d["copy_rows"] = np.asarray(rows, dtype=np.int32)
                    d["copy_cols"] = np.asarray(cols, dtype=np.int32)
                    d["copy_dsts"] = np.asarray(dsts, dtype=np.int32)
                    d["tab_rows"] = np.asarray(tab)
            parts.append(d)
            finals.append(s.final)
            stochs.append(stoch)
        return tuple(parts), tuple(finals), tuple(stochs)

    def _splice_partial(self, part: _Partial, logits):
        """Final chunk done: sample the first token from its logits, copy
        pages / splice the accumulated sub-cache into the reserved lane, and
        activate it — the single-request mirror of ``_admit``'s tail."""
        eng = self.engine
        req = part.req
        spec = self._effective_spec(req)
        sub_state = S.lane_state([spec], 1)
        if self._is_stochastic(spec):
            first_tok, sub_state = eng._sample(logits, sub_state)
        else:
            first_tok = eng._sample(logits)
        lane = part.lane
        lanes = np.asarray([lane])
        if self.page_size is not None:
            self._copy_pages(part.sub_cache, [part.plan], lanes)
            self._register_prefix(req, part.plan)
        lane_idx = jnp.asarray(lanes, jnp.int32)
        self.cache = slot_update(eng.cfg, self.cache, lane_idx, part.sub_cache)
        self.sstate = S.slot_update(self.sstate, lane_idx, sub_state)
        budget = int(part.budget)
        self.tok = self.tok.at[lane].set(first_tok[0])
        self.out_buf = self.out_buf.at[lane].set(0)
        self.out_buf = self.out_buf.at[lane, 0].set(first_tok[0])
        self.n_gen = self.n_gen.at[lane].set(1)
        self.budget = self.budget.at[lane].set(budget)
        self.p = self.p.at[lane].set(
            (first_tok[0] != eng.stop_token) & (budget > 1))
        self._lane_pending[lane] = False
        self._lane_stoch[lane] = self._is_stochastic(spec)

    # ------------------------------------------------------------------
    # paged admission plumbing
    # ------------------------------------------------------------------

    def _paged_spec(self):
        return get_model(self.engine.cfg).paged_cache_spec(self.engine.cfg)

    def _page_in(self, pages: list, entries: list):
        """Swap-in: scatter checksum-verified host-store ``entries`` into
        freshly allocated ``pages`` (one batched jitted write, pid vector
        padded to a power of two aimed at the trash page).  The pages then
        seed the admission prefill exactly like resident shared pages; the
        host entries stay (content-addressed) for future hits."""
        with self.obs.span("swap_in", pages=len(pages)):
            kpad = _next_pow2(len(pages))
            pids = np.full((kpad,), self.trash_page, np.int32)
            pids[:len(pages)] = pages
            blocks = {}
            for pk, proto in entries[0].items():
                rows = [e[pk] for e in entries]
                rows += [np.zeros_like(proto)] * (kpad - len(rows))
                blocks[pk] = np.stack(rows)
            self.stats["dispatches"] += 1
            self.stats["swap_in_pages"] += len(pages)
            self.cache = self.engine._scatter_blocks(
                self.cache, jnp.asarray(pids), blocks)
            # pin the written pools back to canonical placement so the
            # round's fused dispatch doesn't retrace on a drifted layout
            self._reshard()

    def _spill_pages(self, freed: list):
        """Dying-page exit: spill indexed pages to the host store (one
        batched gather; skipped for pages already stored under their prefix
        key), then drop them — and their subtrees — from the radix index."""
        if self.host_swap is not None:
            spill = []
            for pid in freed:
                pfx = self.prefix_index.prefix_of(pid)
                if pfx is not None and pfx not in self.host_swap:
                    spill.append((pid, pfx))
            if spill:
                with self.obs.span("swap_out", pages=len(spill)):
                    kpad = _next_pow2(len(spill))
                    pids = np.full((kpad,), self.trash_page, np.int32)
                    pids[:len(spill)] = [pid for pid, _ in spill]
                    self.stats["dispatches"] += 1
                    blocks = self.engine._gather_blocks(self.cache,
                                                        jnp.asarray(pids))
                    blocks = self._block_on(blocks, "swap_out")
                    for i, (_, pfx) in enumerate(spill):
                        self.host_swap.put(pfx, {k: b[i]
                                                 for k, b in blocks.items()})
                    self.stats["swap_out_pages"] += len(spill)
        for pid in freed:
            self.prefix_index.drop(pid)

    def _seed_arrays(self, plans, n_pad):
        """Seed table + per-row shared length for prefix-seeded admission
        (None when no plan shares anything).  Swapped-in pages seed exactly
        like resident shared pages — their content is in the pool by the
        time the seed gather runs (``_page_in`` writes eagerly at plan
        time)."""
        if not any(pl.shared or pl.swapped for pl in plans):
            return None
        ps = self.page_size
        seed_tab = np.zeros((n_pad, self.n_pages), np.int32)
        shared_len = np.zeros((n_pad,), np.int32)
        for i, pl in enumerate(plans):
            chain = pl.shared + pl.swapped
            seed_tab[i, :len(chain)] = chain
            shared_len[i] = len(chain) * ps
        return seed_tab, shared_len

    def _seed_shared_prefix(self, sub_cache, plans, n_pad):
        """Gather resident shared-prefix pages into the prefill sub-cache so
        suffix rows attend over the donor's K/V (positions [0, pos0))."""
        seed = self._seed_arrays(plans, n_pad)
        if seed is None:
            return sub_cache
        return self.engine._seed_pages(self.cache, sub_cache, seed[0],
                                       seed[1], self.max_len)

    def _page_copy_plan(self, plans):
        """Block-copy plan for freshly prefilled rows: (row, logical col,
        physical dst) triples plus the page-table rows to install (tail-
        padded with the lane's LAST private page so clamped out-of-budget
        writes from retired lanes can never touch a page another request
        owns)."""
        ps = self.page_size
        rows, cols, dsts = [], [], []
        tab_rows = np.zeros((len(plans), self.n_pages), np.int32)
        for i, pl in enumerate(plans):
            n_sh = len(pl.shared) + len(pl.swapped)   # seeded, not prefilled
            n_used = PG.pages_needed(pl.plen, ps)
            for j in range(n_sh, n_used):
                rows.append(i)
                cols.append(j)
                dsts.append(pl.new[j - n_sh])
            ids = pl.shared + pl.swapped + pl.new
            tab_rows[i, :len(ids)] = ids
            tab_rows[i, len(ids):] = pl.new[-1]
        return rows, cols, dsts, tab_rows

    def _copy_pages(self, sub_cache, plans, lanes):
        """Scatter-store freshly prefilled K/V blocks into their allocated
        pages and install the page-table rows (unfused executor)."""
        rows, cols, dsts, tab_rows = self._page_copy_plan(plans)
        self.cache = self.engine._install_pages(
            self.cache, sub_cache, jnp.asarray(rows, dtype=jnp.int32),
            jnp.asarray(cols, dtype=jnp.int32),
            jnp.asarray(dsts, dtype=jnp.int32), jnp.asarray(tab_rows),
            jnp.asarray(lanes, jnp.int32))
        for i, pl in enumerate(plans):
            self.lane_pages[int(lanes[i])] = pl.shared + pl.swapped + pl.new

    def _register_prefix(self, req: Request, plan: _PagePlan):
        """Make this request's full prompt pages discoverable for sharing.
        Called at COMMIT time (the splice is riding this round's dispatch),
        never at plan time — a rolled-back plan must need no index surgery.
        Swapped-in pages register like fresh ones: they are new page ids
        whose content just arrived from the host store."""
        if not self.prefix_sharing or req.extras:
            return
        ps = self.page_size
        parent = plan.shared[-1] if plan.shared else -1
        ids = plan.shared + plan.swapped + plan.new
        for j in range(len(plan.shared), plan.plen // ps):
            self.prefix_index.register(
                parent, req.tokens[j * ps:(j + 1) * ps], ids[j],
                prefix=req.tokens[:(j + 1) * ps].tobytes())
            parent = ids[j]

    def _harvest(self):
        """Collect lanes whose request left the active partition (pending
        chunked-prefill lanes are reserved, not finished)."""
        with self.obs.span("harvest"):
            p_h, out_all, ngen_all = self._block_on(
                (self.p, self.out_buf, self.n_gen), "harvest")
            finished = np.flatnonzero((self.lane_rid >= 0) & ~p_h
                                      & ~self._lane_pending)
            if finished.size == 0:
                return
            out = out_all[finished]
            n_gen = ngen_all[finished]
            t = time.perf_counter()
            freed: list = []
            for j, lane in enumerate(finished):
                rid = int(self.lane_rid[lane])
                n = int(n_gen[j])
                reason = (FinishReason.PREEMPTED_RESUMED
                          if self._rid_preempts.get(rid)
                          else FinishReason.DONE)
                self.results[rid] = {"tokens": out[j, :n].copy(),
                                     "n_generated": n,
                                     "finished_at": self.now,
                                     "finish_reason": reason}
                self.req_times[rid]["finished"] = t
                self._live_req.pop(rid, None)
                self.obs.request_end(rid, n_generated=n,
                                     finished_at=self.now,
                                     reason=reason.value)
                self.lane_rid[lane] = -1
                self._lane_stoch[lane] = False
                if self.page_size is not None:
                    for pid in self.lane_pages.pop(int(lane)):
                        if self.allocator.release(pid):
                            freed.append(pid)
            if self.page_size is not None:
                if freed:
                    self._spill_pages(freed)
                # retired lanes keep decoding architecturally until their
                # slot is refilled: repoint their table rows at the trash
                # page so the freed pages can be reused without interference
                self.cache["page_table"] = self.cache["page_table"].at[
                    jnp.asarray(finished, jnp.int32)].set(self.trash_page)

    def _maybe_compact(self):
        """SVE ``compact`` over the lane vector: squeeze live lanes to the
        lowest indices when occupancy falls below the threshold."""
        if not self.queue:
            # lane density only pays off when admission is about to splice
            # into the tail; during a drain there is nothing to buy with a
            # whole-cache gather
            return
        occupied = self.lane_rid >= 0
        occ = occupied.sum() / self.capacity
        if occ >= self.compact_threshold or self.compact_threshold <= 0:
            return
        if not occupied.any():
            return
        # already dense at the front? nothing to move
        n_live = int(occupied.sum())
        if occupied[:n_live].all():
            return
        with self.obs.span("compact", live=n_live):
            self._compact(occupied)

    def _compact(self, occupied):
        # the SVE compact permutation (partition.compact_perm) computed
        # host-side — a stable argsort of the inactive flag — so deciding to
        # compact never blocks on the device
        perm = np.argsort(~occupied, kind="stable")
        perm_idx = jnp.asarray(perm, jnp.int32)
        # on a paged cache this moves page-table ROWS only — the pools (the
        # actual KV bytes) never move, so compaction cost is O(n_pages), not
        # O(cache)
        self.cache = gather_lanes(self.engine.cfg, self.cache, perm_idx)
        self.sstate = S.gather_lanes(self.sstate, perm_idx)
        self.out_buf = jnp.take(self.out_buf, perm_idx, axis=0)
        self.tok = jnp.take(self.tok, perm_idx, axis=0)
        self.p = jnp.take(self.p, perm_idx, axis=0) & jnp.asarray(
            occupied[perm])
        self.n_gen = jnp.take(self.n_gen, perm_idx, axis=0)
        self.budget = jnp.take(self.budget, perm_idx, axis=0)
        self.lane_rid = self.lane_rid[perm]
        self._lane_stoch = self._lane_stoch[perm]
        self._lane_pending = self._lane_pending[perm]
        self._host_ngen = self._host_ngen[perm]
        new_of = {int(old): new for new, old in enumerate(perm)}
        for part in self._partials:
            part.lane = new_of[part.lane]
        if self.page_size is not None:
            self.lane_pages = {new: self.lane_pages[int(old)]
                               for new, old in enumerate(perm)
                               if int(old) in self.lane_pages}
        if self._stash is not None:
            # the in-flight round's handles describe the OLD lane order:
            # permute them (queued device gathers) and re-prefetch, and move
            # the snapshot views the same way, so the delayed harvest reads
            # a coherent picture
            st = self._stash
            st["p"] = jnp.take(st["p"], perm_idx, axis=0)
            st["out"] = jnp.take(st["out"], perm_idx, axis=0)
            st["ngen"] = jnp.take(st["ngen"], perm_idx, axis=0)
            for a in (st["p"], st["out"], st["ngen"]):
                a.copy_to_host_async()
            st["lane_rid"] = st["lane_rid"][perm]
            st["pending"] = st["pending"][perm]
            st["admitted"] = [new_of[l] for l in st["admitted"]]
        self.stats["compactions"] += 1
