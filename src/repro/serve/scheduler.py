"""Continuous-batching scheduler: SVE compact/partition semantics for traffic.

The serving batch is a vector of request LANES.  A lane's lifecycle is the
paper's §2.3.4 partition algebra applied to traffic instead of loop strips:

  * **admission** — a queued request is prefilled (as part of a sub-batch)
    and spliced into a free lane via ``repro.models.slot_update``: a pure
    index scatter along each cache array's declared lane axis.
  * **decode** — the engine's jitted ``_decode_chunk`` runs bounded bursts;
    per-lane stop tokens / budgets shrink the active partition *inside* XLA.
  * **harvest** — lanes that left the partition surrender their tokens and
    become free slots.
  * **compaction** — when occupancy drops below ``compact_threshold``, the
    survivors are squeezed into the lowest-numbered lanes with the SVE
    ``compact`` permutation (``partition.compact_perm``) applied to the cache
    (``gather_lanes``) and every per-lane side table.  Lanes stay dense, so
    admission always splices into the tail and throughput is a function of
    ACTIVE lanes, not peak batch size.

Everything that moves request state is an index gather/scatter; nothing is
recompiled when traffic gets ragged — the vector-length-agnostic contract.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import partition as PT
from repro.models import gather_lanes, slot_update

from .engine import ServeEngine


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival`` is in scheduler decode-step units
    (0 = available immediately); the scheduler never admits a request before
    its arrival time, which is what the Poisson serving benchmark drives."""
    rid: int
    tokens: np.ndarray                      # (S,) prompt token ids
    max_new_tokens: Optional[int] = None    # default: engine budget
    arrival: float = 0.0
    extras: Optional[dict] = None           # modality extras (cross_emb, ...)


class ContinuousBatchingScheduler:
    """Serve a stream of requests over a fixed-capacity lane vector.

    Parameters
    ----------
    engine: a ``ServeEngine`` (supplies the jitted prefill/decode-chunk fns).
    capacity: number of request lanes (the vector length of the batch).
    max_len: cache sequence capacity per lane (>= prompt + budget).
    chunk: decode steps per burst between admission opportunities.
    compact_threshold: occupancy fraction below which live lanes are
        compacted to the front (the knob; 0 disables compaction).
    """

    def __init__(self, engine: ServeEngine, *, capacity: int, max_len: int,
                 chunk: int = 8, compact_threshold: float = 0.5):
        if engine.cfg.family == "encdec":
            raise NotImplementedError(
                "encdec caches need src_emb/src_len at allocation time; "
                "serve encdec batches via ServeEngine.generate instead")
        self.engine = engine
        self.capacity = capacity
        self.max_len = max_len
        self.chunk = chunk
        self.compact_threshold = compact_threshold

        self.queue: collections.deque[Request] = collections.deque()
        self.results: dict[int, dict] = {}
        self._next_rid = 0
        self.now = 0.0                       # decode-step clock

        b = capacity
        self.lane_rid = np.full((b,), -1, np.int64)   # -1 = free lane
        self.cache = engine.make_cache(b, max_len)
        max_out = engine.max_new_tokens
        self.out_buf = jnp.zeros((b, max_out), jnp.int32)
        self.tok = jnp.full((b,), engine.stop_token, jnp.int32)
        self.p = jnp.zeros((b,), bool)                # active partition
        self.n_gen = jnp.zeros((b,), jnp.int32)
        self.budget = jnp.zeros((b,), jnp.int32)
        self.stats = {"steps": 0, "decode_steps": 0, "lane_steps": 0,
                      "active_lane_steps": 0, "compactions": 0,
                      "occupancy_trace": []}

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def submit(self, tokens, *, max_new_tokens: Optional[int] = None,
               arrival: float = 0.0, extras: Optional[dict] = None) -> int:
        """Queue a request; returns its rid."""
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim != 1:
            raise ValueError(f"prompt must be 1-D, got shape {tokens.shape}")
        if len(tokens) > self.max_len:
            raise ValueError(
                f"prompt length {len(tokens)} exceeds lane capacity "
                f"max_len={self.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, tokens, max_new_tokens, arrival,
                                  extras))
        return rid

    def occupancy(self) -> float:
        return float((self.lane_rid >= 0).sum()) / self.capacity

    def step(self):
        """One scheduling round: compact, admit, decode a chunk, harvest."""
        self._maybe_compact()
        self._admit()
        occupied = self.lane_rid >= 0
        self.stats["occupancy_trace"].append(float(occupied.sum())
                                             / self.capacity)
        if occupied.any():
            eng = self.engine
            gen_before = int(self.n_gen.sum())
            (self.cache, self.out_buf, self.tok, self.p,
             self.n_gen, steps) = eng._decode_chunk(
                eng.params, self.cache, self.out_buf, self.tok, self.p,
                self.n_gen, self.budget, n_steps=self.chunk)
            # the jitted loop exits early once every lane retires, and lanes
            # die mid-chunk: charge what actually ran (each active lane-step
            # commits exactly one token, so the n_gen delta is exact)
            steps = int(steps)
            self.stats["decode_steps"] += steps
            self.stats["lane_steps"] += steps * self.capacity
            self.stats["active_lane_steps"] += int(self.n_gen.sum()) - gen_before
            # the clock is in decode-step units: advance by what actually ran
            self.now += steps
        else:
            self.now += self.chunk              # idle tick: wait for arrivals
        self.stats["steps"] += 1
        self._harvest()

    def run(self) -> dict[int, dict]:
        """Drain the queue and all live lanes; returns {rid: result}."""
        while self.queue or (self.lane_rid >= 0).any():
            self.step()
        return self.results

    # ------------------------------------------------------------------
    # lane lifecycle
    # ------------------------------------------------------------------

    def _free_lanes(self):
        return np.flatnonzero(self.lane_rid < 0)

    def _due(self, req: Request) -> bool:
        return req.arrival <= self.now

    def _admit(self):
        """Prefill due queued requests as one sub-batch and splice them into
        free lanes (slot_update = the in-place `.at[]` scatter).

        The whole queue is scanned (a not-yet-due request must not block due
        ones behind it); FIFO order is preserved among the due.  One prefill
        sub-batch must stack homogeneously, so only requests with the same
        extras keys are admitted together — the rest wait for the next round.
        """
        free = self._free_lanes()
        batch_reqs: list[Request] = []
        rest: list[Request] = []
        extras_keys = None
        for req in self.queue:
            if len(batch_reqs) >= len(free) or not self._due(req):
                rest.append(req)
                continue
            keys = frozenset(req.extras) if req.extras else frozenset()
            if extras_keys is None:
                extras_keys = keys
            if keys != extras_keys:
                rest.append(req)
                continue
            batch_reqs.append(req)
        if not batch_reqs:
            return
        self.queue = collections.deque(rest)
        lanes = free[:len(batch_reqs)]
        eng = self.engine
        n = len(batch_reqs)
        # bucket the prefill shape (rows to a power of two, columns to a
        # power of two capped at max_len) so a ragged trace compiles a
        # BOUNDED set of prefill programs instead of one per (n, plen) pair
        n_pad = min(_next_pow2(n), self.capacity)
        plen = max(len(r.tokens) for r in batch_reqs)
        plen_pad = min(_next_pow2(plen), self.max_len)
        toks = np.zeros((n_pad, plen_pad), np.int32)
        lens = np.ones((n_pad,), np.int32)          # dummy rows: 1-token pad
        for i, r in enumerate(batch_reqs):
            toks[i, :len(r.tokens)] = r.tokens
            lens[i] = len(r.tokens)
        batch = {"tokens": jnp.asarray(toks), "lens": jnp.asarray(lens)}
        if batch_reqs[0].extras:
            for k in batch_reqs[0].extras:
                batch[k] = jnp.stack([jnp.asarray(r.extras[k])
                                      for r in batch_reqs]
                                     + [jnp.zeros_like(jnp.asarray(
                                         batch_reqs[0].extras[k]))] *
                                     (n_pad - n))

        sub_cache = eng.make_cache(n_pad, self.max_len, batch)
        logits, sub_cache = eng._prefill(eng.params, batch, sub_cache)
        first_tok = eng._sample(logits)[:n]
        if n_pad > n:                               # drop the dummy rows
            sub_cache = gather_lanes(eng.cfg, sub_cache,
                                     jnp.arange(n, dtype=jnp.int32))

        # ---- splice the sub-batch into the recycled lanes ----
        lane_idx = jnp.asarray(lanes, jnp.int32)
        self.cache = slot_update(eng.cfg, self.cache, lane_idx, sub_cache)
        budgets = np.asarray(
            [min(eng.max_new_tokens if r.max_new_tokens is None
                 else r.max_new_tokens,
                 eng.max_new_tokens,
                 self.max_len - int(lens[i]))
             for i, r in enumerate(batch_reqs)], np.int32)
        self.tok = self.tok.at[lane_idx].set(first_tok)
        self.out_buf = self.out_buf.at[lane_idx].set(0)
        self.out_buf = self.out_buf.at[lane_idx, 0].set(first_tok)
        self.n_gen = self.n_gen.at[lane_idx].set(1)
        self.budget = self.budget.at[lane_idx].set(jnp.asarray(budgets))
        alive = (first_tok != eng.stop_token) & (jnp.asarray(budgets) > 1)
        self.p = self.p.at[lane_idx].set(alive)
        for i, r in enumerate(batch_reqs):
            self.lane_rid[lanes[i]] = r.rid

    def _harvest(self):
        """Collect lanes whose request left the active partition."""
        finished = np.flatnonzero((self.lane_rid >= 0) & ~np.asarray(self.p))
        if finished.size == 0:
            return
        out = np.asarray(self.out_buf[finished])
        n_gen = np.asarray(self.n_gen[finished])
        for j, lane in enumerate(finished):
            rid = int(self.lane_rid[lane])
            n = int(n_gen[j])
            self.results[rid] = {"tokens": out[j, :n].copy(),
                                 "n_generated": n,
                                 "finished_at": self.now}
            self.lane_rid[lane] = -1

    def _maybe_compact(self):
        """SVE ``compact`` over the lane vector: squeeze live lanes to the
        lowest indices when occupancy falls below the threshold."""
        if not self.queue:
            # lane density only pays off when admission is about to splice
            # into the tail; during a drain there is nothing to buy with a
            # whole-cache gather
            return
        occupied = self.lane_rid >= 0
        occ = occupied.sum() / self.capacity
        if occ >= self.compact_threshold or self.compact_threshold <= 0:
            return
        if not occupied.any():
            return
        # already dense at the front? nothing to move
        n_live = int(occupied.sum())
        if occupied[:n_live].all():
            return
        perm = np.asarray(PT.compact_perm(jnp.asarray(occupied)))
        perm_idx = jnp.asarray(perm, jnp.int32)
        self.cache = gather_lanes(self.engine.cfg, self.cache, perm_idx)
        self.out_buf = jnp.take(self.out_buf, perm_idx, axis=0)
        self.tok = jnp.take(self.tok, perm_idx, axis=0)
        self.p = jnp.take(self.p, perm_idx, axis=0) & jnp.asarray(
            occupied[perm])
        self.n_gen = jnp.take(self.n_gen, perm_idx, axis=0)
        self.budget = jnp.take(self.budget, perm_idx, axis=0)
        self.lane_rid = self.lane_rid[perm]
        self.stats["compactions"] += 1
