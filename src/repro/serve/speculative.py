"""Speculative decoding — the first-fault contract at serving scale.

A small draft model runs K tokens ahead (the speculative vector load); the
target model then verifies the whole window before anything commits.
Acceptance is the maximal matching prefix — ``brkb`` over the mismatch
predicate, exactly the FFR partition of paper §2.3.3: lanes before the first
fault commit, the first faulting lane is re-executed architecturally (here:
the target's own token is substituted), everything after is discarded and
retried next round.

Under STOCHASTIC sampling (``sampling=`` carries per-lane
``repro.sample.SamplingParams``) the equality predicate generalizes to
distribution-preserving rejection sampling (``repro.sample.rejection``):
accept draft token x_i with probability min(1, p_i(x_i)/q_i(x_i)), re-draw
the first fault from the residual norm(max(p−q, 0)) — the committed stream
is then EXACTLY target-alone sampling, so speculation stays lossless
instead of asserting greedy.  Greedy lanes (and ``sampling=None``) keep the
exact-match predicate and commit bit-identically to the deterministic path.
The spec window applies temperature/top-k/top-p/min-p per lane; repetition/
presence penalties are not applied inside the window (their vocab predicate
would have to be rebuilt after every accepted token — a serialized
dependency the window algebra deliberately avoids).

NOTE: verification currently issues K+1 single-token target decodes (teacher
forcing through the decode cache), so the latency win of real speculative
decoding is not yet realized — that needs a windowed ``extend`` entry point
(prefill-style forward at q_offset=pos returning logits at every window
position) in each model family; the acceptance algebra here is independent
of that change.

The implementation is BATCHED: every request lane carries its own speculation
window, and each per-round step is the partition algebra applied row-wise —
``accept_prefix`` for acceptance, ``whilelt``-style budget masks for commit
truncation, and SVE ``lastb`` to extract the next feed token from each lane's
committed partition.  No lane count is special-cased; caches roll back by a
per-lane ``pos`` vector because every attention read is predicated by
``kv_lens = pos + 1`` — stale slots are architecturally inert, the same
trick that makes FFR re-execution free.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import sample as S
from repro.core import partition as PT
from repro.core import predicate as P
from repro.models import get_model


def speculative_decode(target_cfg, target_params, draft_cfg, draft_params,
                       prompt, *, n_tokens: int, k_draft: int = 4,
                       max_len: int | None = None, lens=None,
                       stop_token: int | None = None, sampling=None):
    """Batched speculative decoding (greedy matching or rejection sampling).

    prompt: (B, S) token ids (+ optional per-lane ``lens``).  Every lane
    speculates/commits independently each round; a lane leaves the active
    partition when it hits ``stop_token`` or its ``n_tokens`` budget.
    ``sampling``: None (greedy — bit-identical to the pre-sampling path), a
    single ``SamplingParams``, a per-lane sequence, or a lane state dict.

    Returns (tokens, stats).  For B == 1 tokens is (n_tokens,) and
    ``stats["accept_counts"]`` is a list of ints (legacy single-lane API);
    for B > 1 tokens is (B, n_tokens) and accept_counts holds per-round
    (B,) arrays.  stats also carries ``n_generated`` (B,).
    """
    tmodel, dmodel = get_model(target_cfg), get_model(draft_cfg)
    b, s = prompt.shape
    max_len = max_len or (s + n_tokens + k_draft + 1)
    lens = (jnp.full((b,), s, jnp.int32) if lens is None
            else jnp.asarray(lens, jnp.int32))
    state = None
    if sampling is not None:
        state = sampling if isinstance(sampling, dict) \
            else S.lane_state(sampling, b)

    tcache = tmodel.make_cache(target_cfg, b, max_len)
    dcache = dmodel.make_cache(draft_cfg, b, max_len)
    tlog, tcache = tmodel.prefill(target_params, target_cfg,
                                  {"tokens": prompt, "lens": lens}, tcache)
    _, dcache = dmodel.prefill(draft_params, draft_cfg,
                               {"tokens": prompt, "lens": lens}, dcache)

    decode_t = jax.jit(lambda p, b_, c: tmodel.decode(p, target_cfg, b_, c))
    decode_d = jax.jit(lambda p, b_, c: dmodel.decode(p, draft_cfg, b_, c))

    if state is None:
        cur = S.greedy_tokens(tlog)                # (B,) first target token
    else:
        cur, state = S.sample(tlog, state)
    out = jnp.zeros((b, n_tokens), jnp.int32)
    out = out.at[:, 0].set(cur)
    n_gen = jnp.ones((b,), jnp.int32)
    alive = n_gen < n_tokens
    if stop_token is not None:
        alive = alive & (cur != stop_token)

    kp1 = k_draft + 1
    j = jnp.arange(kp1, dtype=jnp.int32)[None, :]   # window lane index
    rows = jnp.arange(b)[:, None]
    accepted_hist = []

    while bool(jnp.any(alive)):
        pos0 = tcache["pos"]                       # (B,) committed lengths
        if state is not None:
            # one key split per round; draft proposals fold tags 2+i, the
            # acceptance/residual draws inside speculative_accept fold 0/1
            state, round_key = S.split_keys(state)

        # ---- draft speculates K tokens per lane (the speculative load) ----
        dtoks, qs = [], []
        dtok = cur
        for i in range(k_draft):
            dlog, dcache = decode_d(draft_params, {"token": dtok[:, None]},
                                    dcache)
            if state is None:
                dtok = S.greedy_tokens(dlog)
            else:
                ml = S.process_logits(dlog, state)
                ki = jax.vmap(jax.random.fold_in)(
                    round_key, jnp.full((b,), 2 + i, jnp.uint32))
                dtok = jnp.where(state["greedy"], S.greedy_tokens(dlog),
                                 S.gumbel_argmax(ml, ki))
                qs.append(jax.nn.softmax(ml, axis=-1))
            dtoks.append(dtok)
        # one extra decode writes the last draft token's K/V, so a fully
        # accepted window needs no special case (rollback truncates instead)
        _, dcache = decode_d(draft_params, {"token": dtok[:, None]}, dcache)
        draft = jnp.stack(dtoks, axis=1)           # (B, K)

        window = jnp.concatenate([cur[:, None], draft], axis=1)  # (B, K+1)

        # ---- target verifies the whole window (teacher forcing) ----
        tlogs = []
        for i in range(kp1):
            tl, tcache = decode_t(target_params,
                                  {"token": window[:, i:i + 1]}, tcache)
            tlogs.append(tl)
        tgt_next = S.greedy_tokens(jnp.stack(tlogs, axis=1))  # (B, K+1)

        # ---- FFR acceptance: brkb over the per-lane fault predicate ----
        if state is None:
            match = draft == tgt_next[:, :-1]        # (B, K)
            acc = PT.accept_prefix(match)            # maximal prefix per lane
            n_acc = P.cntp(acc)                      # (B,)
            # committed window: accepted draft tokens, then the target's own
            # token at the first fault (the architectural retry)
            fix = jnp.take_along_axis(tgt_next, n_acc[:, None], axis=1)
        else:
            q = jnp.stack(qs, axis=1)                # (B, K, V)
            p_probs = jax.nn.softmax(
                S.process_logits(
                    jnp.stack(tlogs, axis=1).reshape(b * kp1, -1),
                    S.gather_lanes(state, jnp.repeat(jnp.arange(b), kp1))
                ).reshape(b, kp1, -1), axis=-1)      # (B, K+1, V)
            acc, fix1 = S.speculative_accept(draft, q, p_probs, tgt_next,
                                             state["greedy"], round_key)
            n_acc = P.cntp(acc)
            fix = fix1[:, None]
        accepted_hist.append(jnp.where(alive, n_acc, -1))   # -1 = dead lane

        draft_ext = jnp.concatenate([draft, fix], axis=1)            # (B, K+1)
        commit = jnp.where(j < n_acc[:, None], draft_ext, fix)       # (B, K+1)

        # valid partition of the commit window: whilelt against each lane's
        # remaining budget, then brka on the stop predicate (stop commits,
        # nothing after it does)
        remaining = n_tokens - n_gen                                 # (B,)
        valid = (j < (n_acc + 1)[:, None]) & (j < remaining[:, None])
        valid = valid & alive[:, None]
        if stop_token is not None:
            valid = PT.brka(valid, commit == stop_token)

        # scatter committed tokens at each lane's write cursor; invalid
        # window slots are routed out of bounds and dropped, so they can
        # never clobber a valid lane's write
        cols = jnp.where(valid, n_gen[:, None] + j, n_tokens)
        out = out.at[rows, cols].set(commit, mode="drop")
        n_commit = P.cntp(valid)                                     # (B,)
        n_gen = n_gen + n_commit

        # ---- roll caches back to the committed position ----
        # Stale slots beyond pos are inert (whilelt predication by kv_lens);
        # dead lanes keep their old pos, live lanes advance by n_acc + 1.
        stopped = (jnp.any(valid & (commit == stop_token), axis=1)
                   if stop_token is not None else jnp.zeros((b,), bool))
        new_pos = jnp.where(alive, pos0 + n_acc + 1, pos0)
        tcache = _rollback(tcache, new_pos)
        dcache = _rollback(dcache, new_pos)

        # SVE lastb: the next feed token is each lane's last committed one
        cur = jnp.where(alive & (n_commit > 0),
                        PT.lastb(valid, commit), cur)
        alive = alive & ~stopped & (n_gen < n_tokens)

    counts = [np.asarray(c) for c in accepted_hist]
    if b == 1:
        flat = [int(c[0]) for c in counts]
        stats = {"accept_counts": flat,
                 "mean_accepted": (sum(flat) / len(flat) if flat else 0.0),
                 "k_draft": k_draft,
                 "n_generated": np.asarray(n_gen)}
        return out[0, :n_tokens], stats
    live = np.concatenate([c[c >= 0] for c in counts]) if counts else np.array([])
    mean = float(live.mean()) if live.size else 0.0
    stats = {"accept_counts": counts, "mean_accepted": mean,
             "k_draft": k_draft, "n_generated": np.asarray(n_gen)}
    return out, stats


def _rollback(cache, new_pos):
    """Set the per-lane cache position (stale slots beyond pos are inert:
    every attention read is predicated by kv_lens = pos + 1 — whilelt makes
    rollback free, no memory needs clearing)."""
    cache = dict(cache)
    cache["pos"] = jnp.broadcast_to(new_pos, cache["pos"].shape)
    return cache
