"""Speculative decoding — the first-fault contract at serving scale.

A small draft model runs K tokens ahead (the speculative vector load); the
target model verifies all K in ONE forward pass.  Acceptance is the maximal
matching prefix — ``brkb`` over the mismatch predicate, exactly the FFR
partition of paper §2.3.3: lanes before the first fault commit, the first
faulting lane is re-executed architecturally (here: the target's own token is
substituted), everything after is discarded and retried next round.

This implementation is greedy-match speculative decoding (deterministic
targets), which keeps the FFR analogy exact: accepted ⇔ bit-identical to
what the target would have produced alone (asserted in tests).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import partition as PT
from repro.core import predicate as P
from repro.models import get_model


def _greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def speculative_decode(target_cfg, target_params, draft_cfg, draft_params,
                       prompt, *, n_tokens: int, k_draft: int = 4,
                       max_len: int | None = None):
    """Greedy speculative decoding for a single sequence (B=1 lanes are the
    draft positions — the 'vector' here is the speculation window).

    Returns (tokens (n_tokens,), stats dict with acceptance counts).
    """
    tmodel, dmodel = get_model(target_cfg), get_model(draft_cfg)
    b, s = prompt.shape
    assert b == 1
    max_len = max_len or (s + n_tokens + k_draft + 1)

    tcache = tmodel.make_cache(target_cfg, 1, max_len)
    dcache = dmodel.make_cache(draft_cfg, 1, max_len)
    lens = jnp.array([s], jnp.int32)
    tlog, tcache = tmodel.prefill(target_params, target_cfg,
                                  {"tokens": prompt, "lens": lens}, tcache)
    dlog, dcache = dmodel.prefill(draft_params, draft_cfg,
                                  {"tokens": prompt, "lens": lens}, dcache)

    out = []
    cur = _greedy(tlog)                      # first token from the target
    out.append(int(cur[0]))
    accepted_hist = []

    decode_t = jax.jit(lambda p, b_, c: tmodel.decode(p, target_cfg, b_, c))
    decode_d = jax.jit(lambda p, b_, c: dmodel.decode(p, draft_cfg, b_, c))
    prefill_t = jax.jit(lambda p, b_, c: tmodel.prefill(p, target_cfg, b_, c))

    while len(out) < n_tokens:
        # ---- draft speculates k tokens (the speculative load) ----
        draft_toks = []
        dtok = cur
        dc = dcache
        for _ in range(k_draft):
            dlog, dc = decode_d(draft_params, {"token": dtok[:, None]}, dc)
            dtok = _greedy(dlog)
            draft_toks.append(dtok)
        window = jnp.stack([cur] + draft_toks, axis=1)      # (1, K+1)

        # ---- target verifies the window in one pass ----
        # prefill-style forward over the window against the current cache:
        # logits at every window position (teacher forcing)
        tlogs = []
        tc = tcache
        for i in range(window.shape[1]):
            tl, tc = decode_t(target_params, {"token": window[:, i:i + 1]}, tc)
            tlogs.append(tl)
        tlogs = jnp.stack(tlogs, axis=1)                    # (1, K+1, V)
        tgt_next = _greedy(tlogs[0])                        # (K+1,)

        # ---- FFR acceptance: brkb over the mismatch predicate ----
        draft_vec = jnp.stack([t[0] for t in draft_toks])   # (K,)
        match = draft_vec == tgt_next[:-1]
        acc = PT.accept_prefix(match)                       # maximal prefix
        n_acc = int(P.cntp(acc))
        accepted_hist.append(n_acc)

        # accepted tokens commit; the first mismatching lane is replaced by
        # the target's own token (the architectural retry of the first fault)
        commit = [int(draft_vec[i]) for i in range(n_acc)]
        commit.append(int(tgt_next[n_acc]))
        for t in commit:
            out.append(t)
            if len(out) >= n_tokens:
                break

        # ---- roll caches back to the committed position ----
        # Rejected lanes' K/V are inert (whilelt predication by pos) and the
        # already-written accepted K/V stays valid, so rollback = set pos.
        if n_acc == k_draft:
            # fully-accepted window: the draft never wrote K/V for its last
            # speculation; one extra decode keeps its cache gap-free
            _, dc = decode_d(draft_params, {"token": draft_toks[-1][:, None]}, dc)
        n_commit = n_acc + 1
        new_pos = tcache["pos"] + n_commit
        tcache = _rollback(tc, new_pos)
        dcache = _rollback(dc, new_pos)
        cur = jnp.asarray([out[-1]], jnp.int32)

    stats = {"accept_counts": accepted_hist,
             "mean_accepted": (sum(accepted_hist) / len(accepted_hist)
                               if accepted_hist else 0.0),
             "k_draft": k_draft}
    return jnp.asarray(out[:n_tokens], jnp.int32), stats


def _rollback(cache, new_pos):
    """Set the cache position (stale slots beyond pos are inert: every
    attention read is predicated by kv_lens = pos + 1 — whilelt makes
    rollback free, no memory needs clearing)."""
    cache = dict(cache)
    cache["pos"] = jnp.broadcast_to(new_pos, cache["pos"].shape)
    return cache
