from .loss import cross_entropy_loss  # noqa: F401
from .step import make_serve_fns, make_train_step, init_state  # noqa: F401
