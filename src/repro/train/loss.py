"""Losses.  Cross-entropy is computed in f32 with the padded-vocab slots
already masked to -inf by unembed; labels < 0 are ignored (padding).

The f32 upcasts are chunked over the sequence axis (lax.scan) so the peak
f32 temp is (B, chunk, V) instead of (B, S, V) — at command-r scale (V=256k)
that is the difference between ~0.5 GB and ~8 GB per device."""

from __future__ import annotations

import jax
import jax.numpy as jnp

_CHUNK = 256


def _ce_terms(lg_chunk, labels_chunk, z_loss):
    valid = labels_chunk >= 0
    lab = jnp.maximum(labels_chunk, 0)
    lg = lg_chunk.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, lab[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, lse - ll, 0.0)
    tot = jnp.sum(nll)
    if z_loss:
        tot = tot + z_loss * jnp.sum(jnp.where(valid, jnp.square(lse), 0.0))
    return tot, jnp.sum(valid.astype(jnp.float32))


def cross_entropy_loss(logits, labels, *, z_loss: float = 0.0,
                       chunk: int = _CHUNK):
    """logits: (B, S, V); labels: (B, S) int32, -1 = ignore."""
    b, s, v = logits.shape
    if s % chunk != 0 or s <= chunk:
        tot, cnt = _ce_terms(logits, labels, z_loss)
        return tot / jnp.maximum(cnt, 1.0)
    nc = s // chunk
    lg = logits.reshape(b, nc, chunk, v).transpose(1, 0, 2, 3)
    lb = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    def step(carry, xs):
        tot, cnt = carry
        lg_c, lb_c = xs
        t, c = _ce_terms(lg_c, lb_c, z_loss)
        return (tot + t, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0), jnp.float32(0)),
                                 (lg, lb))
    return tot / jnp.maximum(cnt, 1.0)
