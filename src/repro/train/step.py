"""Train / serve step factories — the functions the launcher jits under pjit.

The train step is one fused fwd+bwd+AdamW update; params and optimizer state
shard per the logical-axis rules (FSDP over 'data', TP over 'model', DP over
'pod'×'data'); metrics come out replicated.  ``serve`` returns prefill and
decode step functions against donated caches.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import get_model
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_warmup

from .loss import cross_entropy_loss


def init_state(key, cfg):
    """Real initialization (small models / examples).  Returns (state, axes)."""
    model = get_model(cfg)
    params, axes = model.init(key, cfg)
    return {"params": params, "opt": adamw_init(params),
            "step": jnp.zeros((), jnp.int32)}, axes


def abstract_state(cfg):
    """ShapeDtypeStruct state for lowering (no allocation) + axes trees."""
    model = get_model(cfg)
    params = jax.eval_shape(
        lambda k: model.init(k, cfg)[0], jax.random.PRNGKey(0))
    opt = jax.eval_shape(adamw_init, params)
    state = {"params": params, "opt": opt,
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    p_axes = model.axes(cfg)
    state_axes = {"params": p_axes, "opt": {"m": p_axes, "v": p_axes,
                                            "count": ()},
                  "step": ()}
    return state, state_axes


def make_train_step(cfg, *, peak_lr=3e-4, warmup=100, total=10000,
                    grad_clip=1.0, lb_coef=0.02, z_coef=1e-3,
                    z_loss=0.0, microbatch: int = 1) -> Callable:
    """One fused fwd+bwd+AdamW step.

    ``microbatch`` > 1 splits the global batch into that many accumulation
    chunks via lax.scan (activation memory / microbatch; grads accumulate in
    f32 sharded like params).  The split is data-sharding-preserving: the
    batch dim is reshaped (B,) -> (B/m, m) then transposed, so each microstep
    keeps every data shard busy (no resharding).
    """
    model = get_model(cfg)

    def loss_fn(params, batch):
        logits, aux = model.train_logits(params, cfg, batch)
        loss = cross_entropy_loss(logits, batch["labels"], z_loss=z_loss)
        if "lb_loss" in aux:
            loss = loss + lb_coef * aux["lb_loss"] + z_coef * aux["router_z"]
        return loss, aux

    def _grads(params, batch):
        if microbatch <= 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

        def split(x):
            b = x.shape[0]
            assert b % microbatch == 0, (b, microbatch)
            xs = x.reshape((b // microbatch, microbatch) + x.shape[1:])
            return jnp.moveaxis(xs, 1, 0)       # (m, B/m, ...) shard-local

        mbatch = jax.tree.map(split, batch)

        def mstep(carry, mb):
            gsum, loss_sum, aux_sum = carry
            (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb)
            gsum = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32),
                                gsum, g)
            loss_sum = loss_sum + loss
            aux_sum = jax.tree.map(lambda a, b_: a + b_, aux_sum, aux)
            return (gsum, loss_sum, aux_sum), None

        gz = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        aux0 = jax.eval_shape(lambda p, b_: loss_fn(p, b_)[1], params,
                              jax.tree.map(lambda x: x[0], mbatch))
        aux0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), aux0)
        (gsum, loss_sum, aux_sum), _ = jax.lax.scan(
            mstep, (gz, jnp.float32(0), aux0), mbatch)
        inv = 1.0 / microbatch
        return ((loss_sum * inv,
                 jax.tree.map(lambda a: a * inv, aux_sum)),
                jax.tree.map(lambda g: g * inv, gsum))

    def train_step(state, batch):
        (loss, aux), grads = _grads(state["params"], batch)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr = cosine_warmup(state["step"], peak_lr=peak_lr, warmup=warmup,
                           total=total)
        params, opt = adamw_update(grads, state["opt"], state["params"], lr=lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        metrics.update({k: v for k, v in aux.items()})
        return {"params": params, "opt": opt, "step": state["step"] + 1}, metrics

    return train_step


def make_serve_fns(cfg):
    model = get_model(cfg)

    def prefill_step(params, batch, cache):
        return model.prefill(params, cfg, batch, cache)

    def decode_step(params, batch, cache):
        return model.decode(params, cfg, batch, cache)

    return prefill_step, decode_step
