"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates a REDUCED config of the same family — small
width/depth/experts/tables — and runs one forward + one train step on CPU,
asserting output shapes and no NaNs.  The FULL configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_config
from repro.models import get_model
from repro.train.step import init_state, make_train_step

# per-arch reduction overrides: same family/topology, tiny dims
REDUCE = dict(
    n_layers=4, d_model=64, d_ff=128, vocab_size=128, head_dim=16,
    n_heads=4, n_kv_heads=2, param_dtype="float32", compute_dtype="float32",
    n_cross_tokens=16,
)
PER_ARCH = {
    "llama_3_2_vision_11b": dict(n_layers=10, cross_attn_group=5),
    "olmoe_1b_7b": dict(n_experts=8, top_k=2),
    "moonshot_v1_16b_a3b": dict(n_experts=8, top_k=2, first_k_dense=1,
                                d_ff_dense=160, n_shared_experts=1),
    "stablelm_3b": dict(),
    "command_r_plus_104b": dict(),
    "stablelm_12b": dict(),
    "gemma3_27b": dict(local_window=16, local_global_period=2),
    "zamba2_1_2b": dict(n_layers=5, ssm_state=16, ssm_headdim=16,
                        ssm_chunk=16, shared_attn_period=2),
    "mamba2_130m": dict(ssm_state=16, ssm_headdim=16, ssm_chunk=16,
                        n_heads=1, n_kv_heads=1, d_ff=0),
    "seamless_m4t_large_v2": dict(n_enc_layers=2, n_dec_layers=2, n_layers=4),
}


def reduced_config(arch):
    cfg = get_config(arch)
    over = dict(REDUCE)
    over.update(PER_ARCH[arch])
    # keep family-defining fields from the full config (activation, norms,
    # parallel_block, qk_norm, tie_embeddings, rope...) — only dims shrink
    return cfg.replace(**over)


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.RandomState(seed)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s))),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)))}
    if cfg.family == "dense" and cfg.cross_attn_group:
        batch["cross_emb"] = jnp.asarray(
            rng.randn(b, cfg.n_cross_tokens, cfg.d_model).astype(np.float32))
    if cfg.family == "encdec":
        batch["src_emb"] = jnp.asarray(
            rng.randn(b, s, cfg.d_model).astype(np.float32))
        batch["src_lens"] = jnp.full((b,), s, jnp.int32)
    return batch


@pytest.mark.parametrize("arch", all_arch_names())
def test_forward_shapes_and_no_nans(arch):
    cfg = reduced_config(arch)
    model = get_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0), cfg)
    # axes structure mirrors params exactly
    assert (jax.tree.structure(params)
            == jax.tree.structure(axes, is_leaf=lambda x: isinstance(x, tuple)))
    batch = _batch(cfg)
    logits, aux = model.train_logits(params, cfg, batch)
    b, s = batch["tokens"].shape
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32)))), arch
    # padded vocab slots are masked
    if cfg.padded_vocab != cfg.vocab_size:
        assert float(logits[..., cfg.vocab_size:].max()) < -1e29


@pytest.mark.parametrize("arch", all_arch_names())
def test_one_train_step(arch):
    cfg = reduced_config(arch)
    state, _ = init_state(jax.random.PRNGKey(1), cfg)
    step = make_train_step(cfg, peak_lr=1e-3)
    batch = _batch(cfg, seed=1)
    new_state, metrics = jax.jit(step)(state, batch)
    assert float(metrics["loss"]) == float(metrics["loss"])  # not NaN
    assert int(new_state["step"]) == 1
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(new_state["params"]),
                    jax.tree.leaves(state["params"])))
    assert delta > 0.0


def test_exact_assigned_dimensions():
    """The FULL configs carry the exact assigned dims (spot-check all 10)."""
    want = {
        "llama_3_2_vision_11b": (40, 4096, 32, 8, 14336, 128256),
        "olmoe_1b_7b": (16, 2048, 16, 16, 1024, 50304),
        "moonshot_v1_16b_a3b": (48, 2048, 16, 16, 1408, 163840),
        "stablelm_3b": (32, 2560, 32, 32, 6912, 50304),
        "command_r_plus_104b": (64, 12288, 96, 8, 33792, 256000),
        "stablelm_12b": (40, 5120, 32, 8, 13824, 100352),
        "gemma3_27b": (62, 5376, 32, 16, 21504, 262144),
        "zamba2_1_2b": (38, 2048, 32, 32, 8192, 32000),
        "mamba2_130m": (24, 768, 1, 1, 0, 50280),
        "seamless_m4t_large_v2": (48, 1024, 16, 16, 8192, 256206),
    }
    for arch, (L, d, h, kv, ff, v) in want.items():
        cfg = get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == (L, d, h, kv, ff, v), (arch, got)
    # MoE / SSM extras
    assert get_config("olmoe_1b_7b").n_experts == 64
    assert get_config("olmoe_1b_7b").top_k == 8
    assert get_config("moonshot_v1_16b_a3b").top_k == 6
    assert get_config("zamba2_1_2b").ssm_state == 64
    assert get_config("mamba2_130m").ssm_state == 128
