"""Cluster-scale collectives on a multi-device CPU submesh (subprocess so the
forced device count never leaks into other tests)."""

import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
from repro.dist import collectives as C
from repro.dist.collectives import (ordered_psum, pairwise_psum,
                                    compressed_psum, psum, set_psum_mode)
from repro.launch.mesh import make_mesh

if hasattr(jax, "shard_map"):                      # jax >= 0.6
    def smap(f, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:                                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    def smap(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)

mesh = make_mesh((8,), ("data",))
rng = np.random.RandomState(0)
x = rng.randn(8, 16).astype(np.float32)

# ---- ordered_psum: bit-identical to the sequential loop over shards ----
def f(xs):
    return ordered_psum(xs, "data")
out = jax.jit(smap(f, mesh=mesh, in_specs=P("data"), out_specs=P()))(
    jnp.asarray(x).reshape(8, 1, 16))
want = np.zeros((1, 16), np.float32)
for i in range(8):
    want = want + x[i:i+1]                      # strict shard order
np.testing.assert_array_equal(np.asarray(out).reshape(1, 16), want)
print("ordered OK")

# ---- pairwise_psum: deterministic and close to f64 ----
out2 = jax.jit(smap(lambda xs: pairwise_psum(xs, "data"), mesh=mesh,
                    in_specs=P("data"), out_specs=P()))(
    jnp.asarray(x).reshape(8, 1, 16))
np.testing.assert_allclose(np.asarray(out2).reshape(1, 16),
                           x.sum(0, keepdims=True), rtol=1e-5, atol=1e-5)
print("pairwise OK")

# ---- compressed_psum: int8 + error feedback converges like exact mean ----
def step(g_local, err):
    return compressed_psum(g_local, "data", err)
jstep = jax.jit(smap(step, mesh=mesh,
                     in_specs=(P("data"), P("data")),
                     out_specs=(P(), P("data"))))
err = jnp.zeros((8, 1, 16), jnp.float32)
# single round: quantization error bounded by scale
g = jnp.asarray(x).reshape(8, 1, 16)
mean, err = jstep(g, err)
exact = x.mean(0, keepdims=True)
amax = np.abs(x).max()
assert np.abs(np.asarray(mean).reshape(1, 16) - exact).max() <= amax / 127.0 + 1e-6
# error feedback: accumulated mean over T rounds of the SAME grad converges
acc = np.zeros((1, 16), np.float32)
err = jnp.zeros((8, 1, 16), jnp.float32)
T = 50
for _ in range(T):
    m, err = jstep(g, err)
    acc += np.asarray(m).reshape(1, 16)
np.testing.assert_allclose(acc / T, exact, atol=amax / 127.0 / 10, rtol=0)
print("compressed OK")

# ---- psum choice point: mode dispatch (fast/ordered/pairwise) ----
def run_psum(mode):
    set_psum_mode(mode)
    try:
        return np.asarray(jax.jit(smap(
            lambda xs: psum(xs, "data"), mesh=mesh,
            in_specs=P("data"), out_specs=P()))(
            jnp.asarray(x).reshape(8, 1, 16))).reshape(1, 16)
    finally:
        set_psum_mode("fast")

np.testing.assert_array_equal(run_psum("ordered"), want)   # == sequential
np.testing.assert_allclose(run_psum("fast"), x.sum(0, keepdims=True),
                           rtol=1e-5, atol=1e-5)
np.testing.assert_array_equal(run_psum("pairwise"),
                              np.asarray(out2).reshape(1, 16))
# explicit mode argument overrides the process-wide choice
out3 = jax.jit(smap(lambda xs: psum(xs, "data", mode="ordered"), mesh=mesh,
                    in_specs=P("data"), out_specs=P()))(
    jnp.asarray(x).reshape(8, 1, 16))
np.testing.assert_array_equal(np.asarray(out3).reshape(1, 16), want)
try:
    C.set_psum_mode("nope")
except ValueError:
    pass
else:
    raise AssertionError("bad psum mode accepted")
print("psum choice OK")
"""


def test_collectives_on_submesh():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            # force CPU: without this jax probes for
                            # accelerator plugins and can hang on
                            # network lookups in the bare subprocess
                            "JAX_PLATFORMS": "cpu",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stdout + r.stderr
    for tag in ("ordered OK", "pairwise OK", "compressed OK",
                "psum choice OK"):
        assert tag in r.stdout
