"""Data pipeline: determinism, shard-disjointness, restartability, packing."""

import numpy as np

from repro.data import SyntheticLM, make_batches, pack_documents


def test_batches_deterministic_and_restartable():
    src = SyntheticLM(vocab_size=128, seq_len=64, seed=7)
    a = src.batch(step=5, batch_size=8)
    b = src.batch(step=5, batch_size=8)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_shards_partition_the_global_batch():
    src = SyntheticLM(vocab_size=128, seq_len=32, seed=1)
    full_tokens, full_labels, _ = src.batch(step=3, batch_size=8)
    parts = [src.batch(step=3, batch_size=8, shard_index=i, shard_count=4)
             for i in range(4)]
    got = np.concatenate([p[0] for p in parts], axis=0)
    np.testing.assert_array_equal(got, full_tokens)


def test_labels_are_shift_and_masked():
    src = SyntheticLM(vocab_size=128, seq_len=32, seed=2)
    tokens, labels, lens = src.batch(step=0, batch_size=4)
    for r in range(4):
        n = int(lens[r])
        if n > 1:
            np.testing.assert_array_equal(labels[r, :n - 1], tokens[r, 1:n])
        assert (labels[r, n - 1:] == -1).all()


def test_prefetch_iterator_order():
    src = SyntheticLM(vocab_size=64, seq_len=16, seed=3)
    steps = [s for s, _ in make_batches(src, 4, start_step=10, stop_step=15)]
    assert steps == [10, 11, 12, 13, 14]


def test_pack_documents_ragged():
    docs = [np.arange(5), np.arange(7), np.arange(3)]
    rows, lens = pack_documents(docs, seq_len=8)
    assert rows.shape[1] == 8
    # total real tokens preserved
    assert int(lens.sum()) == 15
    # rows except the last are full
    assert (lens[:-1] == 8).all()
