"""First-fault register semantics (paper §2.3.3, Figs. 4–5)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ffr as F
from repro.core import predicate as P


def test_fig4_gather_semantics():
    """Paper Fig. 4: A[2] invalid => lanes 2,3 suppressed; retry starting at
    lane 2 as first-active => it is NOT suppressed (reads fill; caller traps)."""
    base = jnp.arange(8.0)
    idx = jnp.array([0, 1, 100, 3])
    # iteration 1: all lanes governed
    vals, ffr = F.ldff(base, idx, P.ptrue(4))
    assert ffr.tolist() == [True, True, False, False]
    assert vals.tolist() == [0.0, 1.0, 0.0, 0.0]
    # iteration 2: first two lanes done; faulting lane now first-active
    p2 = jnp.array([False, False, True, True])
    vals2, ffr2 = F.ldff(base, idx, p2)
    # brkb over fault: the first ACTIVE lane faults => empty partition,
    # lane 0 of the partition inactive — the caller's "trap" check.
    assert ffr2.tolist() == [False, False, False, False]
    assert not bool(ffr2[2])


@given(st.integers(min_value=0, max_value=400), st.integers(min_value=4, max_value=160))
@settings(max_examples=40, deadline=None)
def test_strlen_matches_python(n, vl):
    buf = np.zeros(n + 64, np.int32)
    buf[:n] = 5
    got = int(F.strlen(jnp.asarray(buf), 0, vl=vl))
    assert got == n


def test_strlen_nonzero_start():
    buf = np.zeros(64, np.int32)
    buf[3:20] = 9
    assert int(F.strlen(jnp.asarray(buf), 3, vl=8)) == 17


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_ldff_partition_is_prefix_of_safe_lanes(data):
    n = data.draw(st.integers(min_value=1, max_value=64))
    vl = data.draw(st.integers(min_value=1, max_value=32))
    base = np.arange(n, dtype=np.float64)
    idx = np.array(data.draw(st.lists(
        st.integers(min_value=-5, max_value=n + 5), min_size=vl, max_size=vl)))
    g = np.array(data.draw(st.lists(st.booleans(), min_size=vl, max_size=vl)), bool)
    vals, ffr = F.ldff(jnp.asarray(base), jnp.asarray(idx), jnp.asarray(g))
    ffr = np.array(ffr)
    fault = (idx < 0) | (idx >= n)
    # reference semantics
    broken = False
    for i in range(vl):
        if g[i] and fault[i]:
            broken = True
        want = g[i] and not broken
        assert ffr[i] == want
        if ffr[i]:
            assert float(vals[i]) == base[idx[i]]
        else:
            assert float(vals[i]) == 0.0
