"""Chunked XLA flash path vs naive oracle: values AND gradients."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import mha_ref

TOL = dict(rtol=2e-5, atol=2e-5)


def _mk(b, hq, hkv, sq, skv, d, seed=0):
    rng = np.random.RandomState(seed)
    return (jnp.asarray(rng.randn(b, hq, sq, d).astype(np.float32)) * 0.5,
            jnp.asarray(rng.randn(b, hkv, skv, d).astype(np.float32)) * 0.5,
            jnp.asarray(rng.randn(b, hkv, skv, d).astype(np.float32)) * 0.5)


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,window", [
    (1, 2, 2, 128, 128, 32, False, None),
    (2, 4, 2, 200, 333, 32, True, None),
    (1, 4, 4, 256, 256, 32, True, 64),
    (2, 2, 1, 17, 90, 16, True, None),
])
def test_xla_flash_matches_oracle(b, hq, hkv, sq, skv, d, causal, window):
    q, k, v = _mk(b, hq, hkv, sq, skv, d)
    got = flash_attention(q, k, v, causal=causal, window=window, impl="xla",
                          bq=64, bk=64)
    want = mha_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_xla_flash_ragged_and_offset():
    q, k, v = _mk(3, 2, 2, 1, 256, 32, seed=1)
    kv_lens = jnp.array([200, 64, 1], jnp.int32)
    got = flash_attention(q, k, v, kv_lens=kv_lens, q_offset=kv_lens - 1,
                          impl="xla", bq=64, bk=64)
    want = mha_ref(q, k, v, kv_lens=kv_lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 48), (False, None)])
def test_xla_flash_gradients_match_oracle(causal, window):
    q, k, v = _mk(2, 4, 2, 96, 160, 16, seed=2)
    kv_lens = jnp.array([160, 100], jnp.int32)

    def f_flash(q, k, v):
        o = flash_attention(q, k, v, kv_lens=kv_lens, causal=causal,
                            window=window, impl="xla", bq=32, bk=64)
        return jnp.sum(jnp.sin(o))

    def f_ref(q, k, v):
        o = mha_ref(q, k, v, kv_lens=kv_lens, causal=causal, window=window)
        return jnp.sum(jnp.sin(o))

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=5e-4, atol=5e-4)


def test_xla_flash_block_invariance():
    """VLA contract on the XLA path: any (bq, bk) gives the same result."""
    q, k, v = _mk(1, 2, 2, 192, 192, 32, seed=3)
    outs = [np.asarray(flash_attention(q, k, v, causal=True, impl="xla",
                                       bq=bq, bk=bk))
            for bq, bk in [(32, 32), (64, 96), (192, 192)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=3e-6, atol=3e-6)


def test_chunked_ce_matches_unchunked():
    from repro.train.loss import cross_entropy_loss
    rng = np.random.RandomState(0)
    logits = jnp.asarray(rng.randn(2, 512, 64).astype(np.float32))
    labels = jnp.asarray(rng.randint(-1, 64, (2, 512)).astype(np.int32))
    a = cross_entropy_loss(logits, labels, chunk=128)
    b = cross_entropy_loss(logits, labels, chunk=1024)   # falls back unchunked
    np.testing.assert_allclose(float(a), float(b), rtol=1e-6)
    ga = jax.grad(lambda x: cross_entropy_loss(x, labels, chunk=128))(logits)
    gb = jax.grad(lambda x: cross_entropy_loss(x, labels, chunk=1024))(logits)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb), rtol=1e-5, atol=1e-7)
