"""Checkpoint atomicity/async + fault-tolerant loop recovery + stragglers."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.runtime import FaultTolerantLoop, StragglerWatchdog


def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {"a": jnp.asarray(rng.randn(4, 8).astype(np.float32)),
            "b": {"c": jnp.asarray(rng.randn(3).astype(np.float32)),
                  "n": jnp.asarray(7, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    out, step = restore_checkpoint(str(tmp_path), like)
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_ignores_tmp(tmp_path):
    save_checkpoint(str(tmp_path), 3, _tree())
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert latest_step(str(tmp_path)) == 3


def test_async_checkpointer_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s))
    ck.wait()
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]


def _toy_problem():
    """y = Wx regression; train_step is jitted pure SGD."""
    rng = np.random.RandomState(0)
    w_true = rng.randn(4, 4).astype(np.float32)
    xs = rng.randn(64, 4).astype(np.float32)
    ys = xs @ w_true.T

    def batch_fn(step):
        i = step % 8
        return (jnp.asarray(xs[i * 8:(i + 1) * 8]),
                jnp.asarray(ys[i * 8:(i + 1) * 8]))

    @jax.jit
    def train_step(state, batch):
        x, y = batch

        def loss_fn(w):
            return jnp.mean(jnp.square(x @ w.T - y))

        loss, g = jax.value_and_grad(loss_fn)(state["w"])
        return ({"w": state["w"] - 0.05 * g, "step": state["step"] + 1},
                {"loss": loss})

    return batch_fn, train_step


def test_ft_loop_recovers_from_injected_faults(tmp_path):
    batch_fn, train_step = _toy_problem()
    loop = FaultTolerantLoop(train_step, batch_fn, ckpt_dir=str(tmp_path),
                             save_every=5, max_retries=3)
    init = {"w": jnp.zeros((4, 4)), "step": jnp.int32(0)}
    faults = {7, 13}

    def injector(step):
        if step in faults:
            faults.discard(step)
            raise RuntimeError(f"injected fault at {step}")

    state, hist = loop.run(init, 20, fault_injector=injector)
    assert loop.recoveries == 2
    losses = [l for _, l in hist]
    assert losses[-1] < losses[0] * 0.5          # still converged
    # deterministic data order: re-running WITHOUT faults gives same final w
    loop2 = FaultTolerantLoop(train_step, batch_fn,
                              ckpt_dir=str(tmp_path / "clean"), save_every=5)
    state2, _ = loop2.run(init, 20)
    np.testing.assert_allclose(np.asarray(state["w"]), np.asarray(state2["w"]),
                               rtol=1e-6)


def test_ft_loop_gives_up_after_max_retries(tmp_path):
    batch_fn, train_step = _toy_problem()
    loop = FaultTolerantLoop(train_step, batch_fn, ckpt_dir=str(tmp_path),
                             save_every=100, max_retries=2)
    init = {"w": jnp.zeros((4, 4)), "step": jnp.int32(0)}

    def injector(step):
        if step == 3:
            raise RuntimeError("permanent fault")

    with pytest.raises(RuntimeError):
        loop.run(init, 10, fault_injector=injector)


def test_straggler_watchdog():
    wd = StragglerWatchdog(alpha=0.5, threshold=2.0, warmup_steps=2)
    for s in range(6):
        assert not wd.observe(s, 1.0)
    assert wd.observe(6, 5.0)                    # flagged
    assert wd.flagged[0][0] == 6
    assert not wd.observe(7, 1.0)                # EWMA not poisoned
