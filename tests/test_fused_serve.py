"""Fused serve program + async overlap harvest: the one-dispatch-per-round
step program and the one-sync-per-round host loop serve BYTE-IDENTICAL tokens
to the legacy multi-dispatch scheduler loop, across all five families, under
ragged arrivals, prefix sharing, chunked prefill, compaction and mixed
greedy/stochastic traffic — plus dispatch/sync-count regression guards."""

import jax
import numpy as np
import pytest

from repro.models import ModelConfig, get_model
from repro.serve import ContinuousBatchingScheduler, SamplingParams, ServeEngine

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=64, param_dtype="float32", compute_dtype="float32")

FAMILY_OVER = {
    "dense": {},
    "moe": dict(first_k_dense=1, n_experts=4, top_k=2, capacity_factor=4.0),
    "ssm": dict(ssm_state=16, ssm_headdim=16, ssm_chunk=4),
    "hybrid": dict(ssm_state=16, ssm_headdim=16, ssm_chunk=4,
                   shared_attn_period=2),
    "encdec": dict(n_enc_layers=2, n_dec_layers=2),
}
SRC_LEN = 12


def _mk_engine(family, seed=0):
    cfg = ModelConfig(name=f"t-{family}", family=family,
                      **{**BASE, **FAMILY_OVER[family]})
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed), cfg)
    return cfg, ServeEngine(cfg, params, max_new_tokens=6, stop_token=7)


def _mk_trace(rng, n, *, family="dense", d_model=64, shared_prefix=None):
    """Ragged Poisson-ish trace: staggered arrivals, ragged prompts and
    budgets, a shared system-prompt fraction, per-request encdec extras."""
    out, t = [], 0.0
    for _ in range(n):
        t += rng.exponential(1.5)
        prompt = rng.randint(1, 64, rng.randint(3, 14))
        if shared_prefix is not None and rng.rand() < 0.5:
            prompt = np.concatenate([shared_prefix, prompt])[:16]
        extras = None
        if family == "encdec":
            sl = int(rng.randint(2, SRC_LEN - 1))
            extras = {"src_emb": rng.randn(sl, d_model).astype(np.float32)}
        out.append((t, prompt, int(rng.randint(3, 8)), extras))
    return out


def _serve(eng, trace, **kw):
    """Mixed greedy/stochastic: every third request samples at T=0.8."""
    sched = ContinuousBatchingScheduler(eng, capacity=4, max_len=24, chunk=3,
                                        compact_threshold=0.5, **kw)
    for rid, (arrival, prompt, max_new, extras) in enumerate(trace):
        sp = (SamplingParams(temperature=0.8, top_p=0.9, seed=rid,
                             greedy=False) if rid % 3 == 0 else None)
        sched.submit(prompt, arrival=arrival, max_new_tokens=max_new,
                     sampling=sp, extras=extras)
    results = sched.run()
    return results, sched.stats


def _assert_identical(a, b, tag):
    assert sorted(a) == sorted(b)
    for rid in a:
        assert a[rid]["n_generated"] == b[rid]["n_generated"], (tag, rid)
        ta, tb = a[rid]["tokens"], b[rid]["tokens"]
        assert ta.dtype == tb.dtype and ta.tobytes() == tb.tobytes(), \
            (tag, rid, ta, tb)


@pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid", "encdec"])
def test_fused_and_overlap_bit_identical_to_legacy(family):
    """Acceptance criterion: fused=True and overlap=True serve byte-identical
    out_bufs to the legacy loop for every family, under ragged arrivals and
    mixed greedy/stochastic traffic."""
    cfg, eng = _mk_engine(family)
    rng = np.random.RandomState(11)
    trace = _mk_trace(rng, 7, family=family, d_model=cfg.d_model)
    kw = {"src_len": SRC_LEN} if family == "encdec" else {}
    legacy, _ = _serve(eng, trace, fused=False, **kw)
    fused, _ = _serve(eng, trace, fused=True, **kw)
    over, _ = _serve(eng, trace, fused=True, overlap=True, **kw)
    _assert_identical(legacy, fused, f"{family}-fused")
    _assert_identical(legacy, over, f"{family}-overlap")


def test_fused_bit_identical_paged_prefix_chunked_compacting():
    """The full combination: paged cache, prefix sharing, chunked prefill,
    lane compaction, mixed samplers — fused and overlap still byte-identical
    to the legacy loop, and no page leaks."""
    cfg, eng = _mk_engine("dense", seed=1)
    rng = np.random.RandomState(12)
    trace = _mk_trace(rng, 10, shared_prefix=rng.randint(1, 64, 8))
    kw = dict(page_size=4, pool_pages=14, prefill_chunk=4)
    legacy, st_l = _serve(eng, trace, fused=False, **kw)
    fused, st_f = _serve(eng, trace, fused=True, **kw)
    over, st_o = _serve(eng, trace, fused=True, overlap=True, **kw)
    _assert_identical(legacy, fused, "paged-fused")
    _assert_identical(legacy, over, "paged-overlap")
    assert st_f["prefill_chunks"] > 0 and st_f["prefix_hits"] > 0
    assert st_f["compactions"] > 0
    # the fused program folds the legacy loop's separate prefill dispatches
    # into the round dispatch
    assert st_f["dispatches"] < st_l["dispatches"]
    assert st_f["dispatches"] <= st_f["steps"]


def test_overlap_single_blocking_sync_per_round():
    """Dispatch-count regression guard: the async overlap loop blocks on the
    device at most ONCE per scheduling round (plus the final stash flush),
    while the legacy loop syncs several times per round."""
    cfg, eng = _mk_engine("dense", seed=2)
    rng = np.random.RandomState(13)
    trace = _mk_trace(rng, 8)
    legacy, st_l = _serve(eng, trace, fused=False)
    over, st_o = _serve(eng, trace, fused=True, overlap=True)
    _assert_identical(legacy, over, "sync-count")
    assert st_o["host_syncs"] <= st_o["steps"] + 1, st_o
    assert st_o["dispatches"] <= st_o["steps"]
    # legacy: >= 3 syncs per decoding round + 1 per harvest
    assert st_l["host_syncs"] > st_l["steps"]
