"""Trip-count-aware HLO analyzer: the roofline's measurement instrument."""

import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, ".")
from benchmarks.hlo_analysis import analyze  # noqa: E402


def _compiled(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_flat_scan_trip_count():
    def body(x, _):
        return x @ x, None

    def f(x):
        return jax.lax.scan(body, x, None, length=10)[0]

    a = analyze(_compiled(f, jnp.zeros((128, 128))))
    want = 10 * 2 * 128 ** 3
    assert abs(a["flops"] - want) / want < 0.01


def test_nested_scan_trip_product():
    def f(x):
        def outer(xx, _):
            def inner(y, _):
                return y @ y, None
            return jax.lax.scan(inner, xx, None, length=4)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]

    a = analyze(_compiled(f, jnp.zeros((64, 64))))
    want = 12 * 2 * 64 ** 3
    assert abs(a["flops"] - want) / want < 0.01


def test_xla_cost_analysis_undercounts_loops():
    """The motivating bug: XLA counts while bodies once (documents why the
    custom analyzer exists).  If XLA ever fixes this, this test will flag it
    and the roofline can switch back."""
    def body(x, _):
        return x @ x, None

    def f10(x):
        return jax.lax.scan(body, x, None, length=10)[0]

    c = jax.jit(f10).lower(jnp.zeros((128, 128))).compile()
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    xla_flops = ca.get("flops", 0.0)
    ours = analyze(c.as_text())["flops"]
    assert ours > 5 * xla_flops          # XLA ~1 body, ours ~10 bodies


def test_gqa_dot_flops_counted_from_operands():
    """einsum with batch dims + contraction: flops derived from shapes."""
    def f(q, k):
        return jnp.einsum("bhqd,bhkd->bhqk", q, k)

    q = jnp.zeros((2, 4, 64, 32))
    k = jnp.zeros((2, 4, 96, 32))
    a = analyze(_compiled(f, q, k))
    want = 2 * 2 * 4 * 64 * 96 * 32
    assert abs(a["flops"] - want) / want < 0.05


def test_collective_bytes_with_trip_multiplier():
    """psum inside a scan must be charged per-iteration."""
    import subprocess
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, ".")
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from benchmarks.hlo_analysis import analyze
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("data",))

def inner(x):
    def body(c, _):
        return jax.lax.psum(c, "data"), None
    return jax.lax.scan(body, x, None, length=7)[0]

if hasattr(jax, "shard_map"):                      # jax >= 0.6
    f = jax.shard_map(inner, mesh=mesh, in_specs=P(), out_specs=P(),
                      check_vma=False)
else:                                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map
    f = shard_map(inner, mesh=mesh, in_specs=P(), out_specs=P(),
                  check_rep=False)
c = jax.jit(f).lower(jnp.zeros((64, 64))).compile()
a = analyze(c.as_text())
per = 64 * 64 * 4
total = a["collective_bytes"]["total"]
assert 6 * per <= total <= 9 * per, (total, per)
print("COLLECTIVE-TRIPS-OK")
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            # force CPU: without this jax probes for
                            # accelerator plugins and can hang on
                            # network lookups in the bare subprocess
                            "JAX_PLATFORMS": "cpu",
                            "HOME": "/root"})
    assert "COLLECTIVE-TRIPS-OK" in r.stdout, r.stdout + r.stderr
