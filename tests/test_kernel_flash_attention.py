"""Flash-attention kernel vs pure-jnp oracle: shape/dtype/mask sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import mha_ref


def _mk(b, hq, hkv, sq, skv, d, dtype, seed=0):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(b, hq, sq, d), dtype) * 0.5
    k = jnp.asarray(rng.randn(b, hkv, skv, d), dtype) * 0.5
    v = jnp.asarray(rng.randn(b, hkv, skv, d), dtype) * 0.5
    return q, k, v


TOL = dict(rtol=2e-2, atol=2e-2)          # bf16-friendly
TOL32 = dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d", [
    (1, 2, 2, 128, 128, 64),       # exact blocks
    (2, 4, 2, 200, 333, 64),       # ragged tails, GQA 2:1
    (1, 8, 1, 64, 512, 128),       # MQA
    (2, 2, 2, 17, 90, 32),         # tiny, below one block
])
@pytest.mark.parametrize("causal", [False, True])
def test_matches_oracle_f32(b, hq, hkv, sq, skv, d, causal):
    q, k, v = _mk(b, hq, hkv, sq, skv, d, jnp.float32)
    got = flash_attention(q, k, v, causal=causal, bq=128, bk=128)
    want = mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL32)


def test_bf16_matches_oracle():
    q, k, v = _mk(2, 4, 4, 130, 150, 64, jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, bq=128, bk=128)
    want = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL)


def test_ragged_kv_lens():
    q, k, v = _mk(3, 2, 2, 64, 256, 64, jnp.float32)
    kv_lens = jnp.array([256, 100, 1], jnp.int32)
    got = flash_attention(q, k, v, kv_lens=kv_lens, bq=128, bk=128)
    want = mha_ref(q, k, v, kv_lens=kv_lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL32)


def test_sliding_window_matches_oracle():
    q, k, v = _mk(1, 4, 2, 256, 256, 64, jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=64, bq=128, bk=128)
    want = mha_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL32)


def test_decode_q_offset():
    """One new token against a 300-token cache: q_offset = cache position."""
    q, k, v = _mk(2, 4, 4, 1, 384, 64, jnp.float32)
    kv_lens = jnp.array([300, 12], jnp.int32)
    q_offset = kv_lens - 1
    got = flash_attention(q, k, v, kv_lens=kv_lens, causal=False,
                          q_offset=q_offset, bq=128, bk=128)
    # oracle: full attention over the valid prefix (causal is vacuous for the
    # last position, so compare against kv_lens-masked full attention)
    want = mha_ref(q, k, v, kv_lens=kv_lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL32)


def test_empty_rows_zeroed():
    q, k, v = _mk(1, 2, 2, 8, 64, 32, jnp.float32)
    kv_lens = jnp.array([0], jnp.int32)
    got = flash_attention(q, k, v, kv_lens=kv_lens, bq=128, bk=128)
    assert np.abs(np.asarray(got)).max() == 0.0


def test_block_size_invariance():
    """The VLA contract: result identical (up to fp) for any block choice."""
    q, k, v = _mk(1, 2, 1, 300, 300, 64, jnp.float32, seed=3)
    outs = [np.asarray(flash_attention(q, k, v, causal=True, bq=bq, bk=bk))
            for bq, bk in [(128, 128), (256, 128), (128, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=3e-6, atol=3e-6)


def test_xla_impl_matches_kernel():
    q, k, v = _mk(2, 4, 2, 96, 160, 64, jnp.float32, seed=5)
    a = flash_attention(q, k, v, causal=True, impl="kernel", bq=128, bk=128)
    b = flash_attention(q, k, v, causal=True, impl="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
