"""MoE dispatch kernel vs oracle + full dispatch/combine vs naive loop."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.moe_dispatch import build_dispatch, moe_positions
from repro.kernels.moe_dispatch.ref import moe_ffn_loop_ref, moe_positions_ref


@pytest.mark.parametrize("t,k,e,tile", [
    (64, 2, 8, 32), (100, 4, 16, 64), (512, 8, 64, 512), (7, 1, 4, 32),
])
def test_positions_match_oracle(t, k, e, tile):
    rng = np.random.RandomState(0)
    ids = jnp.asarray(rng.randint(0, e, (t, k)), jnp.int32)
    pos, counts = moe_positions(ids, e, tile=tile)
    pos_ref, counts_ref = moe_positions_ref(ids, e)
    np.testing.assert_array_equal(np.asarray(pos), np.asarray(pos_ref))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(counts_ref))


@given(st.data())
@settings(max_examples=20, deadline=None)
def test_positions_property(data):
    t = data.draw(st.integers(min_value=1, max_value=60))
    k = data.draw(st.integers(min_value=1, max_value=4))
    e = data.draw(st.integers(min_value=1, max_value=12))
    rng = np.random.default_rng(data.draw(st.integers(0, 1 << 20)))
    ids = jnp.asarray(rng.integers(0, e, (t, k)), jnp.int32)
    pos, counts = moe_positions(ids, e, tile=32)
    pos, counts, ids_np = np.asarray(pos), np.asarray(counts), np.asarray(ids)
    # per expert: positions are exactly 0..count-1 in flattened order
    flat_ids, flat_pos = ids_np.reshape(-1), pos.reshape(-1)
    for ex in range(e):
        got = flat_pos[flat_ids == ex]
        assert sorted(got.tolist()) == list(range(len(got)))
        assert counts[ex] == len(got)


def test_dispatch_tables_roundtrip():
    rng = np.random.RandomState(1)
    t, k, e, cap = 40, 2, 4, 8
    ids = jnp.asarray(rng.randint(0, e, (t, k)), jnp.int32)
    gates = jnp.asarray(rng.rand(t, k).astype(np.float32))
    d = build_dispatch(ids, gates, e, cap)
    table, keep, slot_of = (np.asarray(d["token_table"]), np.asarray(d["keep"]),
                            np.asarray(d["slot_of"]))
    # every kept assignment appears in the table at its slot and nowhere else
    for tok in range(t):
        for s in range(k):
            ex = int(ids[tok, s])
            if keep[tok, s]:
                assert table.reshape(-1)[slot_of[tok, s]] == tok
                assert slot_of[tok, s] // cap == ex
    # dropped = demand beyond capacity
    counts = np.asarray(d["counts"])
    assert int(d["dropped"]) == int(np.maximum(counts - cap, 0).sum())


@pytest.mark.parametrize("impl", ["kernel", "xla"])
def test_full_moe_ffn_matches_naive_loop(impl):
    rng = np.random.RandomState(2)
    t, k, e, cap, dm, f = 48, 2, 6, 10, 16, 32
    x = rng.randn(t, dm).astype(np.float32)
    ids = rng.randint(0, e, (t, k)).astype(np.int32)
    gates = rng.rand(t, k).astype(np.float32)
    w_up = rng.randn(e, dm, f).astype(np.float32) * 0.1
    w_down = rng.randn(e, f, dm).astype(np.float32) * 0.1

    d = build_dispatch(jnp.asarray(ids), jnp.asarray(gates), e, cap, impl=impl)
    xp = jnp.concatenate([jnp.asarray(x), jnp.zeros((1, dm))], axis=0)
    xe = xp[d["token_table"]]                                   # (E, C, D) gather
    h = jnp.maximum(jnp.einsum("ecd,edf->ecf", xe, jnp.asarray(w_up)), 0.0)
    ye = jnp.einsum("ecf,efd->ecd", h, jnp.asarray(w_down))
    ye_flat = jnp.concatenate([ye.reshape(e * cap, dm), jnp.zeros((1, dm))], axis=0)
    contrib = ye_flat[d["slot_of"]]                             # (T, K, D) gather
    y = jnp.sum(contrib * d["gates"][..., None], axis=1)

    want = moe_ffn_loop_ref(x, ids, gates, w_up, w_down, cap)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-4, atol=1e-5)
