"""SSD kernel vs sequential oracle: chunk sweeps, dtypes, ragged lengths."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan import ssd_decode_step, ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

TOL = dict(rtol=3e-4, atol=3e-4)


def _mk(bz, s, h, p, n, dtype=jnp.float32, seed=0):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(bz, s, h, p), dtype)
    dt = jnp.asarray(np.abs(rng.randn(bz, s, h)) * 0.1 + 0.01, dtype)
    A = jnp.asarray(-np.abs(rng.randn(h)) - 0.1, jnp.float32)
    B = jnp.asarray(rng.randn(bz, s, n) * 0.3, dtype)
    C = jnp.asarray(rng.randn(bz, s, n) * 0.3, dtype)
    D = jnp.asarray(rng.randn(h), jnp.float32)
    return x, dt, A, B, C, D


@pytest.mark.parametrize("chunk", [32, 64, 128])
def test_kernel_matches_sequential_oracle(chunk):
    x, dt, A, B, C, D = _mk(2, 256, 3, 16, 32)
    y_ref, h_ref = ssd_ref(x, dt, A, B, C, D)
    y, hT = ssd_scan(x, dt, A, B, C, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), **TOL)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h_ref), **TOL)


def test_chunk_invariance():
    """VLA contract: identical results at every chunk size (= vector length)."""
    x, dt, A, B, C, D = _mk(1, 192, 2, 8, 16, seed=2)
    outs = [np.asarray(ssd_scan(x, dt, A, B, C, D, chunk=c)[0]) for c in (32, 64, 96)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-4, atol=1e-4)


def test_ragged_tail_predication():
    """Sequence shorter than padded length: dt-zeroing must make padded lanes
    inert (state unchanged, outputs for valid prefix equal to unpadded run)."""
    x, dt, A, B, C, D = _mk(2, 100, 2, 8, 16, seed=3)
    y_full, h_full = ssd_ref(x, dt, A, B, C, D)
    y, hT = ssd_scan(x, dt, A, B, C, D, seq_lens=jnp.array([100, 60]), chunk=64)
    np.testing.assert_allclose(np.asarray(y)[0], np.asarray(y_full)[0], **TOL)
    # row 1: only the first 60 steps ran
    y60, h60 = ssd_ref(x[1:2, :60], dt[1:2, :60], A, B[1:2, :60], C[1:2, :60], D)
    np.testing.assert_allclose(np.asarray(y)[1, :60], np.asarray(y60)[0], **TOL)
    np.testing.assert_allclose(np.asarray(hT)[1], np.asarray(h60)[0], **TOL)


def test_xla_impl_matches_kernel():
    x, dt, A, B, C, D = _mk(1, 128, 2, 8, 16, seed=4)
    a = ssd_scan(x, dt, A, B, C, D, chunk=64, impl="kernel")[0]
    b = ssd_scan(x, dt, A, B, C, D, chunk=64, impl="xla")[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_decode_step_matches_scan():
    """Prefill state + N decode steps == full-scan prefix (serving identity)."""
    x, dt, A, B, C, D = _mk(1, 64, 2, 8, 16, seed=5)
    y_all, _ = ssd_ref(x, dt, A, B, C, D)
    _, h = ssd_scan(x[:, :48], dt[:, :48], A, B[:, :48], C[:, :48], D, chunk=16)
    ys = []
    for t in range(48, 64):
        y_t, h = ssd_decode_step(x[:, t], dt[:, t], A, B[:, t], C[:, t], h, D)
        ys.append(y_t)
    got = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_all)[:, 48:], **TOL)


def test_bf16_inputs():
    x, dt, A, B, C, D = _mk(1, 128, 2, 8, 16, seed=6)
    xb, dtb = x.astype(jnp.bfloat16), dt.astype(jnp.bfloat16)
    Bb, Cb = B.astype(jnp.bfloat16), C.astype(jnp.bfloat16)
    y, _ = ssd_scan(xb, dtb, A, Bb, Cb, D, chunk=64)
    y_ref, _ = ssd_ref(xb, dtb, A, Bb, Cb, D)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
                               rtol=5e-2, atol=5e-2)
