"""daxpy + fadda kernels vs oracles: shape/VL/dtype sweeps (paper Figs. 2, §2.4)."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.daxpy import daxpy
from repro.kernels.daxpy.ref import daxpy_ref
from repro.kernels.fadda import fadda
from repro.kernels.fadda.ref import fadda_ref


@pytest.mark.parametrize("length,n,block", [
    (1000, 777, 128), (128, 128, 128), (4096, 4095, 1024), (50, 10, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_daxpy_matches_oracle(length, n, block, dtype):
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(length), dtype)
    y = jnp.asarray(rng.randn(length), dtype)
    got = daxpy(x, y, 2.5, n, block=block)
    want = daxpy_ref(x, y, jnp.asarray(2.5, dtype), n)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-2, atol=1e-2)


@given(st.integers(min_value=1, max_value=600), st.sampled_from([128, 256]))
@settings(max_examples=20, deadline=None)
def test_daxpy_vl_agnostic(n, block):
    """One kernel source, any (n, VL): the Fig. 2 contract."""
    rng = np.random.RandomState(n)
    x = jnp.asarray(rng.randn(n).astype(np.float32))
    y = jnp.asarray(rng.randn(n).astype(np.float32))
    got = daxpy(x, y, -1.25, block=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(daxpy_ref(x, y, -1.25, n)),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("length,n,block", [
    (600, 600, 128), (600, 421, 128), (1024, 1024, 512), (3, 3, 512),
])
def test_fadda_bit_exact(length, n, block):
    rng = np.random.RandomState(1)
    x = rng.randn(length).astype(np.float32)
    got = fadda(jnp.asarray(x), n, block=block)
    assert np.float32(got) == fadda_ref(x, n)


def test_fadda_vl_invariant_but_ordered():
    """Different VLs give the SAME bits (the whole point of fadda); and the
    result differs from the tree sum on an adversarial sequence, proving the
    ordering is real."""
    x = np.array([1e8, 1.0, -1e8, 1.0] * 64, np.float32)
    r128 = np.float32(fadda(jnp.asarray(x), block=128))
    r512 = np.float32(fadda(jnp.asarray(x), block=512))
    assert r128 == r512 == fadda_ref(x)
    assert r128 != np.float32(x.astype(np.float32).sum())  # tree sum loses the 1.0s
