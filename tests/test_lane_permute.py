"""Lane-permutation primitives (SVE compact/splice/lastb) + the cache lane
interface they drive.  Deterministic sweeps (hypothesis-free) so the tier-1
suite always exercises them; see test_partition.py for the property-test
versions of the partition algebra itself."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import partition as PT
from repro.core import predicate as P
from repro.models import ModelConfig, gather_lanes, get_model, slot_update


def _rand_pred(rng, vl):
    return jnp.asarray(rng.rand(vl) < 0.5)


# ---------------------------------------------------------------------------
# compact / splice / lastb semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("vl", [1, 2, 7, 16, 33])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_compact_matches_oracle(vl, seed):
    rng = np.random.RandomState(100 * vl + seed)
    p = _rand_pred(rng, vl)
    x = jnp.asarray(rng.randint(0, 1000, vl))
    got = np.asarray(PT.compact(p, x))
    active = np.asarray(x)[np.asarray(p)]
    want = np.concatenate([active, np.zeros(vl - len(active), np.int64)])
    np.testing.assert_array_equal(got, want.astype(got.dtype))


@pytest.mark.parametrize("vl", [1, 3, 8, 21])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_splice_matches_oracle(vl, seed):
    rng = np.random.RandomState(7 * vl + seed)
    p = _rand_pred(rng, vl)
    a = jnp.asarray(rng.randint(0, 1000, vl))
    b = jnp.asarray(rng.randint(0, 1000, vl))
    got = np.asarray(PT.splice(p, a, b))
    pn, an, bn = np.asarray(p), np.asarray(a), np.asarray(b)
    if pn.any():
        first, last = pn.argmax(), vl - 1 - pn[::-1].argmax()
        seg = an[first:last + 1]
    else:
        seg = an[:0]
    want = np.concatenate([seg, bn[:vl - len(seg)]])
    np.testing.assert_array_equal(got, want)


def test_compact_splice_roundtrip():
    """compact∘splice round-trip: compacting survivors then splicing in the
    inactive-lane values at the tail reconstructs a permutation of x — and
    with a prefix predicate it reconstructs x itself."""
    rng = np.random.RandomState(0)
    for vl in (1, 2, 5, 16, 40):
        for _ in range(5):
            p = _rand_pred(rng, vl)
            x = jnp.asarray(rng.randint(0, 1000, vl))
            n = int(P.cntp(p))
            dense = PT.compact(p, x)
            inactive = PT.compact(~p, x)
            # splice the compacted survivors (a prefix partition of length n)
            # with the compacted inactive values: a permutation of x
            prefix = jnp.arange(vl) < n
            merged = PT.splice(prefix, dense, inactive) if n else inactive
            np.testing.assert_array_equal(np.sort(np.asarray(merged)),
                                          np.sort(np.asarray(x)))
            # prefix predicates are a fixed point of compaction
            np.testing.assert_array_equal(
                np.asarray(PT.compact(prefix, merged))[:n],
                np.asarray(merged)[:n])


def test_compact_perm_is_permutation_and_stable():
    rng = np.random.RandomState(3)
    for vl in (1, 4, 17, 64):
        p = _rand_pred(rng, vl)
        perm = np.asarray(PT.compact_perm(p))
        assert sorted(perm.tolist()) == list(range(vl))
        pn = np.asarray(p)
        n = pn.sum()
        # active indices first, in original order; inactive after, in order
        np.testing.assert_array_equal(perm[:n], np.flatnonzero(pn))
        np.testing.assert_array_equal(perm[n:], np.flatnonzero(~pn))


def test_lastb_lasta():
    p = jnp.asarray([False, True, True, False])
    x = jnp.asarray([10, 20, 30, 40])
    assert int(PT.lastb(p, x)) == 30
    assert int(PT.lasta(p, x)) == 40
    none = jnp.zeros(4, bool)
    assert int(PT.lastb(none, x)) == 40          # architected fallback: lane VL-1
    assert int(PT.lasta(none, x)) == 10
    # batched rows
    pb = jnp.stack([p, jnp.asarray([True, False, False, False])])
    xb = jnp.stack([x, x])
    np.testing.assert_array_equal(np.asarray(PT.lastb(pb, xb)), [30, 10])


# ---------------------------------------------------------------------------
# whilelt dtype promotion + saturating overflow (runs without hypothesis)
# ---------------------------------------------------------------------------

def test_whilelt_index_dtype_follows_inputs():
    # weak Python ints resolve to the default int dtype
    assert np.asarray(P.whilelt(0, 4, 8)).tolist() == [True] * 4 + [False] * 4
    # explicit narrow dtypes promote, never downcast
    p = P.whilelt(jnp.int16(3), jnp.int32(6), 8)
    assert np.asarray(p).tolist() == [True] * 3 + [False] * 5


def test_whilelt_saturates_at_int_max():
    """Near INT_MAX the architected semantics saturate instead of wrapping:
    lanes whose element index overflows must be INACTIVE even though the
    wrapped value would compare < limit."""
    imax = np.int32(np.iinfo(np.int32).max)
    p = np.asarray(P.whilelt(imax - 2, imax, 8))
    # elements imax-2, imax-1 are < imax; imax hits the limit; beyond wraps
    assert p.tolist() == [True, True] + [False] * 6
    # degenerate: start == INT_MAX, limit == INT_MAX -> empty partition
    assert not np.asarray(P.whilelt(imax, imax, 8)).any()


# ---------------------------------------------------------------------------
# cache lane interface: gather_lanes / slot_update
# ---------------------------------------------------------------------------

BASE = dict(n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
            vocab_size=32, param_dtype="float32", compute_dtype="float32")


def test_gather_then_slot_update_roundtrip():
    cfg = ModelConfig(name="t", family="dense", **BASE)
    model = get_model(cfg)
    cache = model.make_cache(cfg, 4, 8)
    rng = np.random.RandomState(0)
    cache = {k: jnp.asarray(rng.randn(*v.shape).astype(np.float32), v.dtype)
             if v.ndim > 1 else jnp.arange(v.shape[0], dtype=v.dtype)
             for k, v in cache.items()}
    # pull lanes [2, 0] out, write them into lanes [1, 3] of a zero cache
    sub = gather_lanes(cfg, cache, jnp.asarray([2, 0]))
    dst = model.make_cache(cfg, 4, 8)
    dst = slot_update(cfg, dst, jnp.asarray([1, 3]), sub)
    np.testing.assert_array_equal(np.asarray(dst["k"][:, 1]),
                                  np.asarray(cache["k"][:, 2]))
    np.testing.assert_array_equal(np.asarray(dst["v"][:, 3]),
                                  np.asarray(cache["v"][:, 0]))
    assert int(dst["pos"][1]) == 2 and int(dst["pos"][3]) == 0
    # untouched lanes stay zero
    assert float(jnp.abs(dst["k"][:, 0]).sum()) == 0.0


@pytest.mark.parametrize("family,kwargs", [
    ("dense", {}),
    ("moe", dict(n_experts=4, top_k=2)),
    ("ssm", dict(ssm_state=8, ssm_headdim=8, ssm_chunk=8)),
])
def test_cache_batch_axes_cover_every_key(family, kwargs):
    cfg = ModelConfig(name="t", family=family, **{**BASE, **kwargs})
    model = get_model(cfg)
    cache = (model.make_cache(cfg, 3, 8) if family != "ssm"
             else model.make_cache(cfg, 3))
    axes = model.cache_batch_axes(cfg)
    assert set(axes) == set(cache)
    for k, v in cache.items():
        assert v.shape[axes[k]] == 3, (k, v.shape, axes[k])
