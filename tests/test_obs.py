"""repro.obs: the zero-sync telemetry contract.

Three pins:

* tracing ON serves BYTE-IDENTICAL tokens at EQUAL dispatch/host-sync
  counts vs tracing OFF, on a run that exercises paging + prefix sharing +
  chunked prefill + compaction + the async overlap harvest all at once —
  observability reads host-side values the serve loop already holds and
  never adds a device sync;
* the streaming log2 histograms reproduce numpy.percentile within their
  bucket resolution (2**(1/SUBDIV) relative) without storing samples;
* the exported Chrome/Perfetto trace passes structural validation (B/E
  nesting per track, monotonic timestamps, all spans closed) and replays
  the round anatomy docs/ARCHITECTURE.md documents: phase spans nest
  inside round spans, plan precedes the dispatch, and every request track
  opens at submit, sees admitted/first_token, and closes at harvest.
"""

import json

import jax
import numpy as np
import pytest

from repro.models import ModelConfig, get_model
from repro.obs import (LogHistogram, MetricsRegistry, Obs, StatsView, Tracer,
                       validate_trace)
from repro.obs.trace import PID_REQUESTS, PID_SERVE
from repro.serve import ContinuousBatchingScheduler, SamplingParams, ServeEngine

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=64, param_dtype="float32", compute_dtype="float32")

FAMILY_OVER = {
    "dense": {},
    "moe": dict(first_k_dense=1, n_experts=4, top_k=2, capacity_factor=4.0),
    "ssm": dict(ssm_state=16, ssm_headdim=16, ssm_chunk=4),
    "hybrid": dict(ssm_state=16, ssm_headdim=16, ssm_chunk=4,
                   shared_attn_period=2),
    "encdec": dict(n_enc_layers=2, n_dec_layers=2),
}
SRC_LEN = 12


def _mk_engine(family="dense"):
    cfg = ModelConfig(name=f"t-obs-{family}", family=family,
                      **{**BASE, **FAMILY_OVER[family]})
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, ServeEngine(cfg, params, max_new_tokens=6, stop_token=7)


@pytest.fixture(scope="module")
def engine():
    return _mk_engine()[1]


def _trace(rng, n, family="dense", d_model=64):
    """Ragged arrivals, ragged prompts/budgets, a shared system prefix on
    half the requests (the prefix-sharing + host-swap traffic shape)."""
    shared = np.arange(1, 9)
    out, t = [], 0.0
    for _ in range(n):
        t += rng.exponential(1.5)
        prompt = rng.randint(1, 64, rng.randint(3, 14))
        if rng.rand() < 0.5:
            prompt = np.concatenate([shared, prompt])[:16]
        extras = None
        if family == "encdec":
            sl = int(rng.randint(2, SRC_LEN - 1))
            extras = {"src_emb": rng.randn(sl, d_model).astype(np.float32)}
        out.append((t, prompt, int(rng.randint(3, 8)), extras))
    return out


def _serve(eng, trace, obs=None, combo=True, **kw):
    """``combo=True`` (the dense-family default) is the all-features-on
    configuration: paged + prefix sharing + chunked prefill + host swap +
    compaction + fused step + async overlap harvest, with mixed
    greedy/stochastic lanes."""
    if combo:
        kw = dict(page_size=4, prefill_chunk=4, host_swap_pages=8, **kw)
    sched = ContinuousBatchingScheduler(
        eng, capacity=4, max_len=24, chunk=3, compact_threshold=0.5,
        fused=True, overlap=True, obs=obs, **kw)
    for rid, (arrival, prompt, max_new, extras) in enumerate(trace):
        sp = (SamplingParams(temperature=0.8, top_p=0.9, seed=rid,
                             greedy=False) if rid % 3 == 0 else None)
        sched.submit(prompt, arrival=arrival, max_new_tokens=max_new,
                     sampling=sp, extras=extras)
    results = sched.run()
    return results, dict(sched.stats)


# ----------------------------------------------------------------------
# the hard contract: tracing observes, never perturbs
# ----------------------------------------------------------------------

def test_tracing_on_off_byte_identity(engine):
    trace = _trace(np.random.RandomState(0), 10)
    r_off, s_off = _serve(engine, trace)
    obs = Obs(tracer=Tracer())
    r_on, s_on = _serve(engine, trace, obs=obs)
    assert r_off.keys() == r_on.keys()
    for rid in r_off:
        assert np.array_equal(r_off[rid]["tokens"], r_on[rid]["tokens"]), (
            f"rid {rid}: tracing changed served tokens")
        assert r_off[rid]["n_generated"] == r_on[rid]["n_generated"]
    assert s_on["dispatches"] == s_off["dispatches"]
    assert s_on["host_syncs"] == s_off["host_syncs"]
    # full stats equality, not just the headline counters
    assert s_on == s_off
    assert len(obs.tracer.events) > 0


@pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid",
                                    "encdec"])
def test_tracing_identity_all_families(family):
    """Acceptance criterion: EVERY family serves byte-identical tokens at
    equal dispatch and host-sync counts with tracing on (fused + overlap
    loop; the paged combo is pinned separately above)."""
    cfg, eng = _mk_engine(family)
    trace = _trace(np.random.RandomState(3), 6, family=family,
                   d_model=cfg.d_model)
    kw = {"src_len": SRC_LEN} if family == "encdec" else {}
    r_off, s_off = _serve(eng, trace, combo=False, **kw)
    r_on, s_on = _serve(eng, trace, combo=False, obs=Obs(tracer=Tracer()),
                        **kw)
    for rid in r_off:
        ta, tb = r_off[rid]["tokens"], r_on[rid]["tokens"]
        assert ta.dtype == tb.dtype and ta.tobytes() == tb.tobytes(), (
            family, rid)
    assert s_on == s_off, family


def test_off_recorder_is_noop(engine):
    """Without a tracer every hook is a no-op (shared NULL_SPAN, immediate
    returns) — nothing accumulates anywhere but the metrics registry."""
    obs = Obs()
    assert not obs.tracing
    span = obs.span("round", xla=True)
    assert span is obs.span("anything")          # the shared singleton
    obs.event("x")
    obs.request_begin(0)
    obs.request_event(0, "y")
    obs.request_end(0)
    assert obs.export("/nonexistent/never-written.json") == 0


# ----------------------------------------------------------------------
# histogram percentiles vs numpy
# ----------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["lognormal", "uniform", "exponential"])
def test_histogram_percentiles_within_bucket_tolerance(dist):
    rng = np.random.RandomState(7)
    if dist == "lognormal":
        vals = rng.lognormal(2.0, 1.0, 4000)
    elif dist == "uniform":
        vals = rng.uniform(0.5, 50.0, 4000)
    else:
        vals = rng.exponential(10.0, 4000) + 0.01
    h = LogHistogram("lat", unit="ms", percentiles=(50, 90, 99))
    for v in vals:
        h.record(float(v))
    # one bucket spans a 2**(1/SUBDIV) relative range; nearest-rank vs
    # linear interpolation adds at most one more bucket of slack
    tol = 2.0 ** (2.0 / LogHistogram.SUBDIV) - 1.0
    for q in (50, 90, 99):
        ref = float(np.percentile(vals, q))
        est = h.percentile(q)
        assert abs(est - ref) / ref <= tol, (dist, q, est, ref)
    assert h.count == len(vals)
    assert h.mean == pytest.approx(float(vals.mean()))


def test_histogram_edge_cases():
    h = LogHistogram("lat")
    assert h.percentile(50) == 0.0               # empty
    h.record(0.0)
    h.record(-1.0)                               # zero bucket
    assert h.percentile(50) == 0.0
    h2 = LogHistogram("one")
    h2.record(3.0)
    assert h2.percentile(50) == pytest.approx(3.0, rel=0.1)
    assert h2.snapshot().keys() == {"one_p50_ms", "one_p99_ms"}


def test_stats_view_is_a_dict_facade():
    reg = MetricsRegistry()
    reg.counter("steps", key="rounds")
    reg.series("occupancy_trace", key="mean_occupancy")
    view = reg.stats_view()
    assert isinstance(view, StatsView)
    view["steps"] += 2
    view["steps"] += 1
    view["occupancy_trace"].append(0.5)
    view["occupancy_trace"].append(1.0)
    view["new_counter"] = 7                      # auto-registers
    assert view["steps"] == 3
    assert dict(view) == {"steps": 3, "occupancy_trace": [0.5, 1.0],
                          "new_counter": 7}
    # snapshot speaks the bench's key language, not the stat names
    assert reg.snapshot() == {"rounds": 3, "mean_occupancy": 0.75,
                              "new_counter": 7}


# ----------------------------------------------------------------------
# trace schema + round-anatomy replay
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_run(engine):
    obs = Obs(tracer=Tracer())
    results, stats = _serve(engine, _trace(np.random.RandomState(1), 8),
                            obs=obs)
    obs.tracer.close()
    return obs.tracer.trace_events(), results, stats


def test_trace_validates(traced_run):
    events, _, _ = traced_run
    assert validate_trace(events) == []
    # round-trips through JSON (what export() writes)
    assert validate_trace(json.loads(json.dumps(events))) == []


def test_trace_replays_round_anatomy(traced_run):
    """The serve-loop track replays docs/ARCHITECTURE.md §1: every phase
    span nests inside a round span, and within a round the plan phase
    precedes the fused dispatch which precedes the (delayed) harvest."""
    events, _, stats = traced_run
    serve = [e for e in events
             if e.get("pid") == PID_SERVE and e.get("ph") in ("B", "E")
             and e.get("tid") == 0]
    depth = 0
    round_depth = None
    rounds = 0
    phases_seen: set = set()
    order: list = []
    orders: list = []
    for ev in serve:
        if ev["ph"] == "B":
            depth += 1
            if ev["name"] == "round":
                assert round_depth is None, "rounds must not nest"
                round_depth = depth
                order = []
                rounds += 1
            elif round_depth is not None:
                assert depth > round_depth, (
                    f"phase {ev['name']} outside a round span")
                if depth == round_depth + 1:
                    order.append(ev["name"])
                    phases_seen.add(ev["name"])
        else:
            if round_depth is not None and depth == round_depth:
                round_depth = None
                orders.append(order)
            depth -= 1
    assert rounds == stats["steps"]
    # the fused path's core phases all occurred somewhere in the run
    assert {"plan", "dispatch", "harvest"} <= phases_seen
    for order in orders:
        if "plan" in order and "dispatch" in order:
            assert order.index("plan") < order.index("dispatch")
        if "dispatch" in order and "harvest" in order:
            assert order.index("dispatch") < order.index("harvest")
    # every sync span carries its reason and nests under the serve track
    syncs = [e for e in events if e.get("name") == "sync"
             and e.get("ph") == "B"]
    assert len(syncs) == stats["host_syncs"]
    assert all(e["args"]["what"] for e in syncs)


def test_trace_request_lifecycles(traced_run):
    """pid 2 carries one track per request: opened at submit, annotated
    with admitted/first_token, closed exactly once at harvest."""
    events, results, _ = traced_run
    tracks: dict = {}
    for ev in events:
        if ev.get("pid") != PID_REQUESTS or ev.get("ph") == "M":
            continue
        tracks.setdefault(ev["tid"], []).append(ev)
    assert set(tracks) == set(results)
    for rid, evs in tracks.items():
        phs = [e["ph"] for e in evs]
        assert phs[0] == "B" and phs[-1] == "E" and phs.count("B") == 1, rid
        assert evs[0]["args"]["prompt_len"] > 0
        names = [e.get("name") for e in evs if e["ph"] == "i"]
        assert "admitted" in names and "first_token" in names, (rid, names)
        assert evs[-1]["args"]["n_generated"] == results[rid]["n_generated"]


def test_validate_trace_catches_malformed():
    ok = [{"ph": "B", "ts": 1.0, "pid": 1, "tid": 0, "name": "a"},
          {"ph": "E", "ts": 2.0, "pid": 1, "tid": 0, "name": "a"}]
    assert validate_trace(ok) == []
    unclosed = ok[:1]
    assert any("never closed" in e for e in validate_trace(unclosed))
    dangling = ok[1:]
    assert any("no open B" in e for e in validate_trace(dangling))
    crossed = [dict(ok[0]), {"ph": "E", "ts": 2.0, "pid": 1, "tid": 0,
                             "name": "b"}]
    assert any("closes B" in e for e in validate_trace(crossed))
    backwards = [dict(ok[0], ts=5.0), dict(ok[1], ts=2.0)]
    assert any("not monotonic" in e for e in validate_trace(backwards))
    bad_ph = [{"ph": "Z", "ts": 1.0, "pid": 1, "tid": 0}]
    assert any("unknown phase" in e for e in validate_trace(bad_ph))


def test_tracer_close_heals_open_spans():
    tr = Tracer()
    tr._emit("B", "round", 0, None)
    tr.request_begin(3, prompt_len=4)
    tr.close()
    assert validate_trace(tr.trace_events()) == []
