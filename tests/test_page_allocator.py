"""Page allocator + prefix index invariants: alloc/free/refcount never
double-frees or leaks pages across randomized submit/retire schedules.

The deterministic seeded schedules always run; the hypothesis variants widen
the search when hypothesis is installed (they skip cleanly otherwise, like
the other property suites)."""

import numpy as np
import pytest

from repro.serve import HostSwapStore, PageAllocator, PrefixIndex


def _check_invariants(alloc: PageAllocator):
    free = alloc.free_pages
    held = int((alloc.refcount > 0).sum())
    assert free + held == alloc.pool_pages          # no leak, no double-count
    assert (alloc.refcount >= 0).all()
    assert len(set(alloc._free)) == len(alloc._free)  # free list has no dups


def _random_schedule(seed: int, pool: int, steps: int):
    """Random interleaving of alloc / retain / release with live tracking."""
    rng = np.random.RandomState(seed)
    alloc = PageAllocator(pool)
    holdings: list[list[int]] = []                  # per-request page lists
    for _ in range(steps):
        op = rng.randint(3)
        if op == 0:                                 # submit: alloc a few
            want = int(rng.randint(1, pool + 2))
            pages = alloc.alloc(want)
            if want > alloc.pool_pages or pages is None:
                assert pages is None or len(pages) == want
            else:
                assert len(pages) == want
                holdings.append(list(pages))
        elif op == 1 and holdings:                  # share: retain a prefix
            donor = holdings[rng.randint(len(holdings))]
            k = int(rng.randint(1, len(donor) + 1))
            for p in donor[:k]:
                alloc.retain(p)
            holdings.append(list(donor[:k]))
        elif op == 2 and holdings:                  # retire: release all
            idx = rng.randint(len(holdings))
            for p in holdings.pop(idx):
                alloc.release(p)
        _check_invariants(alloc)
    for pages in holdings:                          # drain
        for p in pages:
            alloc.release(p)
    _check_invariants(alloc)
    assert alloc.free_pages == alloc.pool_pages     # everything returned
    assert (alloc.refcount == 0).all()


@pytest.mark.parametrize("seed", range(8))
def test_random_schedule_never_leaks_or_double_frees(seed):
    _random_schedule(seed, pool=int(np.random.RandomState(seed).randint(1, 12)),
                     steps=200)


def test_alloc_is_all_or_nothing():
    a = PageAllocator(4)
    assert a.alloc(5) is None
    assert a.free_pages == 4                        # nothing consumed
    pages = a.alloc(4)
    assert sorted(pages) == [0, 1, 2, 3]
    assert a.alloc(1) is None


def test_double_release_raises():
    a = PageAllocator(2)
    (p,) = a.alloc(1)
    assert a.release(p) is True
    with pytest.raises(ValueError, match="double free"):
        a.release(p)


def test_retain_of_free_page_raises():
    a = PageAllocator(2)
    with pytest.raises(ValueError, match="retain of free page"):
        a.retain(0)


def test_release_returns_true_only_at_zero():
    a = PageAllocator(2)
    (p,) = a.alloc(1)
    a.retain(p)
    assert a.release(p) is False                    # sharer still holds it
    assert a.free_pages == 1
    assert a.release(p) is True
    assert a.free_pages == 2


# ---------------------------------------------------------------------------
# prefix index
# ---------------------------------------------------------------------------

def test_prefix_index_lookup_walks_longest_resident_chain():
    idx = PrefixIndex()
    toks = np.arange(12, dtype=np.int32)
    idx.register(-1, toks[0:4], 10)
    idx.register(10, toks[4:8], 11)
    assert idx.lookup(toks, 4) == [10, 11]          # page 2 not indexed
    idx.register(11, toks[8:12], 12)
    assert idx.lookup(toks, 4) == [10, 11, 12]
    # a different prefix shares nothing
    assert idx.lookup(np.arange(1, 13, dtype=np.int32), 4) == []


def test_prefix_index_drop_unindexes_subtree():
    idx = PrefixIndex()
    toks = np.arange(8, dtype=np.int32)
    idx.register(-1, toks[0:4], 5)
    idx.register(5, toks[4:8], 6)
    idx.drop(5)                                     # parent dies
    assert idx.lookup(toks, 4) == []                # child unreachable AND gone
    assert len(idx) == 0
    # page id 5 recycled for a different prompt must not resurrect the chain
    other = np.arange(100, 108, dtype=np.int32)
    idx.register(-1, other[0:4], 5)
    assert idx.lookup(toks, 4) == []
    assert idx.lookup(other, 4) == [5]


def test_prefix_index_same_block_under_different_parents():
    """K/V of a block depends on the WHOLE prefix, so identical token blocks
    under different parents must stay distinct entries."""
    idx = PrefixIndex()
    blk = np.arange(4, dtype=np.int32)
    idx.register(-1, blk, 1)
    idx.register(1, blk, 2)                         # same bytes, parent 1
    assert idx.lookup(np.concatenate([blk, blk]), 4) == [1, 2]
    idx.drop(2)
    assert idx.lookup(np.concatenate([blk, blk]), 4) == [1]


# ---------------------------------------------------------------------------
# hypothesis-widened schedules (optional dependency; the seeded tests above
# must keep running without it, so no module-level importorskip here)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2 ** 31 - 1), pool=st.integers(1, 16),
           steps=st.integers(1, 120))
    def test_property_random_schedules(seed, pool, steps):
        _random_schedule(seed, pool=pool, steps=steps)

    @settings(max_examples=50, deadline=None)
    @given(ops=st.lists(st.tuples(st.integers(0, 2), st.integers(0, 15)),
                        max_size=60))
    def test_property_index_register_drop_consistent(ops):
        """Register/drop in arbitrary order keeps the index internally
        consistent: every indexed page resolves through its own key."""
        idx = PrefixIndex()
        rng = np.random.RandomState(0)
        blocks = [rng.randint(0, 50, 4).astype(np.int32) for _ in range(16)]
        live = set()
        for op, arg in ops:
            if op == 0:                             # register under root
                if arg not in live:
                    idx.register(-1, blocks[arg], arg)
                    live.add(arg)
            elif op == 1 and live:                  # register under a parent
                parent = sorted(live)[arg % len(live)]
                child = arg
                if child not in live and child != parent:
                    idx.register(parent, blocks[child], child)
                    live.add(child)
            elif op == 2 and live:                  # drop
                page = sorted(live)[arg % len(live)]
                idx.drop(page)
                live.discard(page)
                # dropping may cascade to children: resync from the index
                live &= set(idx._key_of)
        for page, key in idx._key_of.items():
            assert idx._child[key] == page
        assert len(idx._child) == len(idx._key_of)
else:
    def test_property_schedules_skipped_without_hypothesis():
        pytest.skip("hypothesis not installed (optional dependency)")


# ---------------------------------------------------------------------------
# host swap store (the eviction tier below the prefix index)
# ---------------------------------------------------------------------------

def test_host_swap_store_lru_eviction_order():
    s = HostSwapStore(2)
    s.put(b"a", {"k": 1})
    s.put(b"b", {"k": 2})
    assert s.get(b"a")["k"] == 1                    # refreshes recency
    s.put(b"c", {"k": 3})                           # evicts b (LRU), not a
    assert b"a" in s and b"c" in s and b"b" not in s
    assert s.evictions == 1 and len(s) == 2
    assert s.get(b"b") is None


def test_host_swap_store_put_is_first_write_wins():
    """Entries are content-addressed by the full prefix bytes, so a second
    put of the same key (the same prefix respilled) must be a no-op — the
    stored pool blocks are immutable."""
    s = HostSwapStore(4)
    s.put(b"a", {"k": 1})
    s.put(b"a", {"k": 9})
    assert s.get(b"a")["k"] == 1
    assert len(s) == 1 and s.evictions == 0


def test_host_swap_store_rejects_zero_capacity():
    with pytest.raises(ValueError):
        HostSwapStore(0)


def test_prefix_index_prefix_of_follows_registration():
    """prefix_of returns the full-prefix bytes recorded at registration (the
    host-store key for a later spill) and dies with the page — a recycled
    page id must never expose the old prefix."""
    idx = PrefixIndex()
    toks = np.arange(8, dtype=np.int32)
    idx.register(-1, toks[0:4], 5, prefix=toks[0:4].tobytes())
    idx.register(5, toks[4:8], 6, prefix=toks[0:8].tobytes())
    assert idx.prefix_of(5) == toks[0:4].tobytes()
    assert idx.prefix_of(6) == toks[0:8].tobytes()
    idx.drop(5)
    assert idx.prefix_of(5) is None
    other = np.arange(50, 54, dtype=np.int32)
    idx.register(-1, other, 5)                      # recycled, no prefix
    assert idx.prefix_of(5) is None


def test_prefix_index_duplicate_register_keeps_first_prefix():
    """Registering the same (parent, block) under a new page is a no-op (the
    resident page wins), so its prefix record must survive unchanged."""
    idx = PrefixIndex()
    blk = np.arange(4, dtype=np.int32)
    idx.register(-1, blk, 1, prefix=b"one")
    idx.register(-1, blk, 2, prefix=b"two")         # duplicate key: ignored
    assert idx.lookup(np.concatenate([blk, blk]), 4) == [1]
    assert idx.prefix_of(1) == b"one"
    assert idx.prefix_of(2) is None


if _HAS_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(cap=st.integers(1, 6),
           ops=st.lists(st.tuples(st.booleans(), st.integers(0, 9)),
                        max_size=80))
    def test_property_host_swap_store_is_bounded_lru(cap, ops):
        """The store tracks a reference LRU model exactly: bounded size,
        least-recently-USED eviction, first-write-wins contents."""
        s = HostSwapStore(cap)
        model: dict = {}                            # insertion-ordered model
        for is_put, arg in ops:
            key = bytes([arg])
            if is_put:
                s.put(key, {"v": arg})
                if key in model:
                    model[key] = model.pop(key)     # duplicate put: refresh
                else:
                    model[key] = arg
                    if len(model) > cap:
                        model.pop(next(iter(model)))  # LRU falls off
            else:
                got = s.get(key)
                if key in model:
                    assert got == {"v": model[key]}
                    model[key] = model.pop(key)     # refresh recency
                else:
                    assert got is None
            assert len(s) <= cap
            assert list(s._store) == list(model)
else:
    def test_property_host_swap_skipped_without_hypothesis():
        pytest.skip("hypothesis not installed")
