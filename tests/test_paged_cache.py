"""Paged KV cache (SVE §2.3.3 gather/scatter): core helpers, bit-identity of
paged decode against the dense engine on ragged stop patterns, prefix sharing
(refcount bump + suffix-only prefill + identical tokens), and the paged flash
attention paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import paging as PG
from repro.kernels.flash_attention import flash_attention
from repro.models import ModelConfig, get_model, paged_view, to_paged
from repro.serve import ContinuousBatchingScheduler, ServeEngine

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=64, param_dtype="float32", compute_dtype="float32")
MAX_LEN = 24

_NOL = {k: v for k, v in BASE.items() if k != "n_layers"}


def _family_cfg(family):
    """Tiny config per family for the native-vs-gather decode matrix."""
    if family == "dense":
        return ModelConfig(name="t", family="dense", **BASE)
    if family == "moe":
        # capacity_factor high enough that nothing drops: MoE is then
        # per-token deterministic and bit-comparable across cache layouts
        return ModelConfig(name="t", family="moe", first_k_dense=1,
                           n_experts=4, top_k=2, capacity_factor=4.0, **BASE)
    if family == "hybrid":
        return ModelConfig(name="t", family="hybrid", n_layers=3,
                           shared_attn_period=2, ssm_state=16, ssm_headdim=16,
                           ssm_chunk=16, **_NOL)
    if family == "encdec":
        return ModelConfig(name="t", family="encdec", n_enc_layers=2,
                           n_dec_layers=2, **BASE)
    if family == "vlm":
        return ModelConfig(name="t", family="dense", n_layers=10,
                           cross_attn_group=5, n_cross_tokens=4, **_NOL)
    raise ValueError(family)


def _family_batch(cfg, rng, b, s):
    batch = {"tokens": jnp.asarray(rng.randint(1, 64, (b, s))),
             "lens": jnp.asarray(rng.randint(3, s + 1, b), jnp.int32)}
    if cfg.family == "encdec":
        batch["src_emb"] = jnp.asarray(
            rng.randn(b, s, cfg.d_model).astype(np.float32))
        batch["src_lens"] = jnp.asarray(rng.randint(2, s + 1, b), jnp.int32)
    if cfg.cross_attn_group:
        batch["cross_emb"] = jnp.asarray(
            rng.randn(b, cfg.n_cross_tokens, cfg.d_model).astype(np.float32))
    return batch


@pytest.fixture(scope="module")
def dense_setup():
    cfg = ModelConfig(name="t", family="dense", **BASE)
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, model, params


def _fresh_reference(eng, prompt, budget=None):
    res = eng.generate({"tokens": jnp.asarray(prompt)[None, :]},
                       max_len=MAX_LEN)
    n = int(res["n_generated"][0])
    if budget is not None:
        n = min(n, budget)
    return np.asarray(res["tokens"][0, :n]), n


# ---------------------------------------------------------------------------
# core paging helpers
# ---------------------------------------------------------------------------

def test_gather_pages_reproduces_dense_layout():
    rng = np.random.RandomState(0)
    P, hkv, ps, d, b, npg = 10, 2, 4, 8, 3, 2
    pool = jnp.asarray(rng.randn(P, hkv, ps, d).astype(np.float32))
    table = jnp.asarray(rng.randint(0, P, (b, npg)), jnp.int32)
    view = PG.gather_pages(pool, table)
    assert view.shape == (b, hkv, npg * ps, d)
    for i in range(b):
        for j in range(npg):
            np.testing.assert_array_equal(
                view[i, :, j * ps:(j + 1) * ps, :], pool[int(table[i, j])])


def test_gather_pages_with_lead_axes():
    rng = np.random.RandomState(1)
    L, P, hkv, ps, d = 3, 6, 2, 4, 8
    pool = jnp.asarray(rng.randn(L, P, hkv, ps, d).astype(np.float32))
    table = jnp.asarray([[5, 0], [1, 3]], jnp.int32)
    view = PG.gather_pages(pool, table, n_lead=1)
    assert view.shape == (L, 2, hkv, 2 * ps, d)
    np.testing.assert_array_equal(view[:, 0, :, :ps, :], pool[:, 5])
    np.testing.assert_array_equal(view[:, 1, :, ps:, :], pool[:, 3])


def test_scatter_page_roundtrip():
    rng = np.random.RandomState(2)
    P, hkv, ps, d, b = 8, 2, 4, 8, 3
    pool = jnp.zeros((P, hkv, ps, d), jnp.float32)
    page_ids = jnp.asarray([6, 1, 3], jnp.int32)
    offsets = jnp.asarray([0, 2, 3], jnp.int32)
    vals = jnp.asarray(rng.randn(b, hkv, d).astype(np.float32))
    pool = PG.scatter_page(pool, page_ids, offsets, vals)
    for i in range(b):
        np.testing.assert_array_equal(
            pool[int(page_ids[i]), :, int(offsets[i]), :], vals[i])
    # every other slot untouched
    assert float(jnp.abs(pool).sum()) == pytest.approx(
        float(jnp.abs(vals).sum()), rel=1e-6)


def test_scatter_block_and_gather_block_inverse():
    rng = np.random.RandomState(3)
    L, P, hkv, ps, d = 2, 7, 2, 4, 8
    pool = jnp.zeros((L, P, hkv, ps, d), jnp.float32)
    ids = jnp.asarray([4, 2], jnp.int32)
    blocks = jnp.asarray(rng.randn(2, L, hkv, ps, d).astype(np.float32))
    pool = PG.scatter_block(pool, ids, blocks, n_lead=1)
    got = PG.gather_block(pool, ids, n_lead=1)
    np.testing.assert_array_equal(got, blocks)


def test_page_whilelt():
    lens = jnp.asarray([0, 1, 8, 9, 24])
    live = PG.page_whilelt(lens, n_pages=3, page_size=8)
    np.testing.assert_array_equal(
        np.asarray(live),
        [[False, False, False], [True, False, False], [True, False, False],
         [True, True, False], [True, True, True]])


# ---------------------------------------------------------------------------
# paged flash attention reads through the page table
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["naive", "xla", "kernel"])
def test_paged_flash_matches_dense(impl):
    rng = np.random.RandomState(0)
    B, Hq, Hkv, D, ps, npg, P = 2, 4, 2, 16, 8, 3, 9
    S = npg * ps
    kd = rng.randn(B, Hkv, S, D).astype(np.float32)
    vd = rng.randn(B, Hkv, S, D).astype(np.float32)
    q = jnp.asarray(rng.randn(B, Hq, 1, D).astype(np.float32))
    perm = rng.permutation(P)[:B * npg]
    table = np.zeros((B, npg), np.int32)
    pool_k = np.zeros((P, Hkv, ps, D), np.float32)
    pool_v = np.zeros((P, Hkv, ps, D), np.float32)
    it = iter(perm)
    for b in range(B):
        for j in range(npg):
            pid = int(next(it))
            table[b, j] = pid
            pool_k[pid] = kd[b, :, j * ps:(j + 1) * ps, :]
            pool_v[pid] = vd[b, :, j * ps:(j + 1) * ps, :]
    kv_lens = jnp.asarray([11, S], jnp.int32)
    q_off = kv_lens - 1
    ref = flash_attention(jnp.asarray(q), jnp.asarray(kd), jnp.asarray(vd),
                          kv_lens=kv_lens, q_offset=q_off, causal=True,
                          impl="xla")
    out = flash_attention(q, jnp.asarray(pool_k), jnp.asarray(pool_v),
                          page_table=jnp.asarray(table), kv_lens=kv_lens,
                          q_offset=q_off, causal=True, impl=impl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# paged scheduler: bit-identity on ragged stop patterns
# ---------------------------------------------------------------------------

def test_paged_decode_bit_identical_to_dense_engine(dense_setup):
    """Acceptance criterion: streamed requests through the PAGED scheduler —
    ragged prompts, ragged budgets, natural stop tokens, lane recycling and
    page reuse — decode bit-identically to fresh dense-engine batches."""
    cfg, _, params = dense_setup
    eng = ServeEngine(cfg, params, max_new_tokens=8, stop_token=7)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, 64, rng.randint(4, 12)) for _ in range(10)]
    budgets = [int(rng.randint(2, 9)) for _ in prompts]
    sched = ContinuousBatchingScheduler(eng, capacity=4, max_len=MAX_LEN,
                                        chunk=4, compact_threshold=0.5,
                                        page_size=8)
    rids = [sched.submit(p, max_new_tokens=bud)
            for p, bud in zip(prompts, budgets)]
    results = sched.run()
    assert sorted(results) == sorted(rids)
    for rid, prompt, bud in zip(rids, prompts, budgets):
        want, n = _fresh_reference(eng, prompt, budget=bud)
        got = results[rid]
        assert got["n_generated"] == n
        np.testing.assert_array_equal(got["tokens"], want)
    # no page leaked and no refcount survived the drain
    assert sched.allocator.free_pages == sched.pool_pages
    assert (sched.allocator.refcount == 0).all()
    assert len(sched.prefix_index) == 0


def test_paged_matches_dense_scheduler_under_memory_pressure(dense_setup):
    """A pool HALF the dense footprint gates admission on pages (waits occur)
    yet still serves every request bit-identically."""
    cfg, _, params = dense_setup
    eng = ServeEngine(cfg, params, max_new_tokens=8, stop_token=7)
    rng = np.random.RandomState(1)
    prompts = [rng.randint(1, 64, rng.randint(4, 12)) for _ in range(8)]
    dense_pages = 4 * (MAX_LEN // 8)
    sched = ContinuousBatchingScheduler(eng, capacity=4, max_len=MAX_LEN,
                                        chunk=4, page_size=8,
                                        pool_pages=dense_pages // 2)
    rids = [sched.submit(p) for p in prompts]
    results = sched.run()
    assert sched.stats["page_waits"] > 0      # admission was page-gated
    for rid, prompt in zip(rids, prompts):
        want, n = _fresh_reference(eng, prompt)
        assert results[rid]["n_generated"] == n
        np.testing.assert_array_equal(results[rid]["tokens"], want)
    assert sched.allocator.free_pages == sched.pool_pages


def test_paged_compaction_moves_tables_not_pools(dense_setup):
    """Lane compaction on a paged cache permutes page-table rows; the pools
    are untouched (same buffers' contents), and results stay bit-identical."""
    cfg, _, params = dense_setup
    eng = ServeEngine(cfg, params, max_new_tokens=12, stop_token=7)
    rng = np.random.RandomState(2)
    wave1 = [rng.randint(1, 64, rng.randint(4, 10)) for _ in range(4)]
    wave2 = [rng.randint(1, 64, rng.randint(4, 10)) for _ in range(3)]
    sched = ContinuousBatchingScheduler(eng, capacity=4, max_len=MAX_LEN,
                                        chunk=2, compact_threshold=0.75,
                                        page_size=8)
    rids1 = [sched.submit(p, max_new_tokens=(12 if i == 2 else 1))
             for i, p in enumerate(wave1)]
    rids2 = [sched.submit(p, arrival=2.0) for p in wave2]
    results = sched.run()
    assert sched.stats["compactions"] >= 1
    for rid, prompt in zip(rids1 + rids2, wave1 + wave2):
        budget = 1 if (rid in rids1 and rid != rids1[2]) else 12
        want, n = _fresh_reference(eng, prompt, budget=budget)
        assert results[rid]["n_generated"] == n
        np.testing.assert_array_equal(results[rid]["tokens"], want)


def test_kernel_paged_decode_matches_dense(dense_setup):
    """paged_attn="kernel": flash reads K/V through the page table inside the
    model's decode (no gathered view) — tokens match the dense engine."""
    cfg, _, params = dense_setup
    ref_eng = ServeEngine(cfg, params, max_new_tokens=8, stop_token=7)
    eng = ServeEngine(cfg, params, max_new_tokens=8, stop_token=7,
                      paged_attn="kernel")
    rng = np.random.RandomState(3)
    prompts = [rng.randint(1, 64, rng.randint(4, 12)) for _ in range(6)]
    sched = ContinuousBatchingScheduler(eng, capacity=4, max_len=MAX_LEN,
                                        chunk=4, page_size=8)
    rids = [sched.submit(p) for p in prompts]
    results = sched.run()
    for rid, prompt in zip(rids, prompts):
        want, n = _fresh_reference(ref_eng, prompt)
        assert results[rid]["n_generated"] == n
        np.testing.assert_array_equal(results[rid]["tokens"], want)


# ---------------------------------------------------------------------------
# prefix sharing
# ---------------------------------------------------------------------------

def test_prefix_sharing_refcount_bump_and_identical_tokens(dense_setup):
    """Acceptance criterion: a second request sharing a prompt prefix admits
    WITHOUT re-prefilling the shared pages — observable as a refcount bump on
    the donor's pages and a suffix-sized prefill — and still produces tokens
    identical to a cold decode of its full prompt."""
    cfg, _, params = dense_setup
    eng = ServeEngine(cfg, params, max_new_tokens=12, stop_token=7)
    rng = np.random.RandomState(3)
    ps = 4
    donor = rng.randint(1, 64, 11)                   # 2 full pages of 4
    sharer = np.concatenate([donor[:8], rng.randint(1, 64, 5)])
    sched = ContinuousBatchingScheduler(eng, capacity=4, max_len=32,
                                        chunk=2, page_size=ps)
    rid_a = sched.submit(donor, max_new_tokens=12)   # long-lived donor
    sched.step()                                     # admit donor
    assert sched.stats["prefix_hits"] == 0
    donor_pages = list(sched.lane_pages[0][:2])
    prefill_before = sched.stats["prefill_tokens"]
    assert (sched.allocator.refcount[donor_pages] == 1).all()

    rid_b = sched.submit(sharer)
    sched.step()                                     # admit sharer (hit)
    assert sched.stats["prefix_hits"] == 1
    assert sched.stats["prefix_hit_tokens"] == 8
    # refcount bump observed on the shared pages while both are resident
    assert (sched.allocator.refcount[donor_pages] == 2).all()
    # the sharer prefilled ONLY its suffix (13 - 8 tokens), not the prefix
    assert sched.stats["prefill_tokens"] - prefill_before == len(sharer) - 8

    results = sched.run()
    for rid, prompt in ((rid_a, donor), (rid_b, sharer)):
        res = eng.generate({"tokens": jnp.asarray(prompt)[None, :]},
                           max_len=32)
        n = int(res["n_generated"][0])
        want = np.asarray(res["tokens"][0, :n])
        assert results[rid]["n_generated"] == n
        np.testing.assert_array_equal(results[rid]["tokens"], want)
    assert sched.allocator.free_pages == sched.pool_pages


def test_prefix_never_shares_the_whole_prompt(dense_setup):
    """A prompt fully covered by resident pages still re-prefills its last
    block: the suffix prefill must produce the next-token logits."""
    cfg, _, params = dense_setup
    eng = ServeEngine(cfg, params, max_new_tokens=8, stop_token=7)
    rng = np.random.RandomState(4)
    ps = 4
    donor = rng.randint(1, 64, 8)                    # exactly 2 pages
    sched = ContinuousBatchingScheduler(eng, capacity=4, max_len=32,
                                        chunk=2, page_size=ps)
    rid_a = sched.submit(donor, max_new_tokens=8)
    sched.step()
    rid_b = sched.submit(donor.copy())               # identical prompt
    sched.step()
    # only ONE page may be shared (the final block re-prefills)
    assert sched.stats["prefix_hit_tokens"] <= len(donor) - 1
    results = sched.run()
    want, n = _fresh_reference(eng, donor)
    for rid in (rid_a, rid_b):
        assert results[rid]["n_generated"] == n
        np.testing.assert_array_equal(results[rid]["tokens"], want)


def test_prefix_pages_outlive_the_donor(dense_setup):
    """The DONOR retiring while the sharer still decodes must not free the
    shared pages: the sharer's references keep them resident."""
    cfg, _, params = dense_setup
    eng = ServeEngine(cfg, params, max_new_tokens=12, stop_token=7)
    rng = np.random.RandomState(5)
    ps = 4
    donor = rng.randint(1, 64, 9)
    sharer = np.concatenate([donor[:8], rng.randint(1, 64, 4)])
    sched = ContinuousBatchingScheduler(eng, capacity=2, max_len=32,
                                        chunk=2, page_size=ps)
    rid_a = sched.submit(donor, max_new_tokens=6)    # donor retires early
    sched.step()                                     # admit donor
    shared = list(sched.lane_pages[0][:2])
    rid_b = sched.submit(sharer, max_new_tokens=12)
    sched.step()                                     # admit sharer (hit)
    assert sched.stats["prefix_hits"] == 1
    assert (sched.allocator.refcount[shared] == 2).all()
    while rid_a not in sched.results:                # run until donor retires
        sched.step()
    assert rid_b not in sched.results                # sharer still decoding
    # donor's references dropped; the sharer's keep the pages resident
    assert (sched.allocator.refcount[shared] == 1).all()
    results = sched.run()
    res = eng.generate({"tokens": jnp.asarray(sharer)[None, :]}, max_len=32)
    n = int(res["n_generated"][0])
    np.testing.assert_array_equal(results[rid_b]["tokens"],
                                  np.asarray(res["tokens"][0, :n]))
    assert results[rid_a]["n_generated"] == 6
    assert sched.allocator.free_pages == sched.pool_pages


def test_prefix_hit_coadmitted_with_longer_cold_request(dense_setup):
    """Regression: a prefix-shared row (pos0 > 0) co-admitted with a longer
    cold request must not have its padded suffix write clamp-shifted over its
    seeded prefix K/V (the admission group-fit guard defers the mismatch).
    Both orders of arrival must produce tokens identical to cold decode."""
    cfg, _, params = dense_setup
    eng = ServeEngine(cfg, params, max_new_tokens=8, stop_token=7)
    rng = np.random.RandomState(8)
    ps, ml = 8, 32
    donor = rng.randint(1, 64, 19)                   # 2 full pages shared
    sharer = np.concatenate([donor[:16], rng.randint(1, 64, 3)])
    cold = rng.randint(1, 64, 24)                    # forces plen_pad 32
    for first, second in ((sharer, cold), (cold, sharer)):
        sched = ContinuousBatchingScheduler(eng, capacity=4, max_len=ml,
                                            chunk=2, page_size=ps)
        rid_d = sched.submit(donor, max_new_tokens=8)
        sched.step()                                 # donor resident
        rid_1 = sched.submit(first)
        rid_2 = sched.submit(second)
        results = sched.run()
        assert sched.stats["prefix_hits"] == 1
        for rid, prompt in ((rid_d, donor), (rid_1, first), (rid_2, second)):
            res = eng.generate({"tokens": jnp.asarray(prompt)[None, :]},
                               max_len=ml)
            n = int(res["n_generated"][0])
            assert results[rid]["n_generated"] == n
            np.testing.assert_array_equal(results[rid]["tokens"],
                                          np.asarray(res["tokens"][0, :n]))
        assert sched.allocator.free_pages == sched.pool_pages
        assert (sched.allocator.refcount == 0).all()


# ---------------------------------------------------------------------------
# paged view bridge + other families
# ---------------------------------------------------------------------------

def test_paged_view_roundtrips_prefill_state(dense_setup):
    """Admitting through pages and gathering the view reproduces the dense
    sub-cache contents for every valid position."""
    cfg, _, params = dense_setup
    eng = ServeEngine(cfg, params, max_new_tokens=4, stop_token=-1)
    rng = np.random.RandomState(6)
    prompt = rng.randint(1, 64, 9)
    sched = ContinuousBatchingScheduler(eng, capacity=2, max_len=16,
                                        chunk=1, page_size=8)
    sched.submit(prompt)
    sched._maybe_compact()
    sched._admit()                                   # prefill + page copy
    view = paged_view(cfg, sched.cache)
    dense = eng.make_cache(1, 16)
    logits, dense = eng._prefill(
        eng.params, {"tokens": jnp.asarray(prompt)[None, :],
                     "lens": jnp.asarray([9]),
                     "pos0": jnp.asarray([0], jnp.int32)}, dense)
    plen = len(prompt)
    np.testing.assert_array_equal(view["k"][:, 0, :, :plen, :],
                                  dense["k"][:, 0, :, :plen, :])
    np.testing.assert_array_equal(view["v"][:, 0, :, :plen, :],
                                  dense["v"][:, 0, :, :plen, :])
    assert int(view["pos"][0]) == plen


def test_hybrid_family_paged_bit_identity():
    cfg = ModelConfig(name="t", family="hybrid", n_layers=3,
                      shared_attn_period=2, ssm_state=16, ssm_headdim=16,
                      ssm_chunk=16, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab_size=64, param_dtype="float32",
                      compute_dtype="float32")
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_new_tokens=6, stop_token=7)
    rng = np.random.RandomState(7)
    prompts = [rng.randint(1, 64, rng.randint(4, 10)) for _ in range(4)]
    sched = ContinuousBatchingScheduler(eng, capacity=2, max_len=16,
                                        chunk=3, page_size=8)
    assert not sched.prefix_sharing          # SSM carry is not paged
    rids = [sched.submit(p) for p in prompts]
    results = sched.run()
    for rid, prompt in zip(rids, prompts):
        res = eng.generate({"tokens": jnp.asarray(prompt)[None, :]},
                           max_len=16)
        n = int(res["n_generated"][0])
        np.testing.assert_array_equal(results[rid]["tokens"],
                                      np.asarray(res["tokens"][0, :n]))
    assert sched.allocator.free_pages == sched.pool_pages


# ---------------------------------------------------------------------------
# native paged decode: per-family bit-identity vs the gather oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["dense", "moe", "hybrid", "encdec", "vlm"])
def test_native_paged_decode_matches_gather_oracle(family):
    """Acceptance criterion: EVERY family decodes a paged cache natively
    (flash attention through the page table, tail-page scatter-stores) with
    token streams identical to both the dense engine and the gather-bridge
    oracle (paged_attn="gather"), on ragged prompt lengths and natural
    stops.  The one-shot ``generate(page_size=)`` road covers the families
    the scheduler does not manage (encdec, vlm)."""
    cfg = _family_cfg(family)
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(11)
    batch = _family_batch(cfg, rng, b=3, s=9)
    native = ServeEngine(cfg, params, max_new_tokens=6, stop_token=7)
    oracle = ServeEngine(cfg, params, max_new_tokens=6, stop_token=7,
                         paged_attn="gather")
    dense = native.generate(batch, max_len=MAX_LEN)
    paged = native.generate(batch, max_len=MAX_LEN, page_size=8)
    gathered = oracle.generate(batch, max_len=MAX_LEN, page_size=8)
    np.testing.assert_array_equal(np.asarray(dense["tokens"]),
                                  np.asarray(gathered["tokens"]))
    np.testing.assert_array_equal(np.asarray(dense["tokens"]),
                                  np.asarray(paged["tokens"]))
    np.testing.assert_array_equal(np.asarray(dense["n_generated"]),
                                  np.asarray(paged["n_generated"]))


def test_to_paged_view_roundtrip(dense_setup):
    """to_paged (identity tables) then paged_view reproduces the dense cache
    bit-exactly — the converter is the inverse of the gather bridge."""
    cfg, _, params = dense_setup
    eng = ServeEngine(cfg, params, max_new_tokens=4)
    rng = np.random.RandomState(12)
    batch = {"tokens": jnp.asarray(rng.randint(1, 64, (2, 9)))}
    cache = eng.make_cache(2, MAX_LEN, batch)
    _, cache = eng._prefill(eng.params, dict(batch, lens=jnp.asarray([9, 5])),
                            cache)
    view = paged_view(cfg, to_paged(cfg, cache, page_size=8))
    for key in ("k", "v", "pos"):
        np.testing.assert_array_equal(np.asarray(view[key]),
                                      np.asarray(cache[key]))


def test_native_paged_never_materializes_view(dense_setup, monkeypatch):
    """Acceptance criterion: with the default (native) engine, no
    ``paged_view`` materialization happens inside the jitted decode step —
    the monkeypatched bridge would raise at trace time."""
    import repro.serve.engine as E

    def boom(*a, **k):
        raise AssertionError("gather bridge used on the native hot path")

    monkeypatch.setattr(E, "paged_view", boom)
    cfg, _, params = dense_setup
    eng = ServeEngine(cfg, params, max_new_tokens=6, stop_token=7)
    rng = np.random.RandomState(13)
    prompts = [rng.randint(1, 64, rng.randint(4, 12)) for _ in range(4)]
    sched = ContinuousBatchingScheduler(eng, capacity=2, max_len=MAX_LEN,
                                        chunk=4, page_size=8)
    rids = [sched.submit(p) for p in prompts]
    results = sched.run()
    assert sorted(results) == sorted(rids)


def test_moe_family_paged_native_bit_identity():
    """MoE through the PAGED scheduler (native decode over the dense-stack
    and expert-stack pools) matches fresh dense generation."""
    cfg = _family_cfg("moe")
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_new_tokens=6, stop_token=7)
    rng = np.random.RandomState(14)
    prompts = [rng.randint(1, 64, rng.randint(4, 10)) for _ in range(5)]
    sched = ContinuousBatchingScheduler(eng, capacity=2, max_len=16,
                                        chunk=3, page_size=8)
    assert not sched.prefix_sharing            # capacity dropping forbids it
    rids = [sched.submit(p) for p in prompts]
    results = sched.run()
    for rid, prompt in zip(rids, prompts):
        res = eng.generate({"tokens": jnp.asarray(prompt)[None, :]},
                           max_len=16)
        n = int(res["n_generated"][0])
        assert results[rid]["n_generated"] == n
        np.testing.assert_array_equal(results[rid]["tokens"],
                                      np.asarray(res["tokens"][0, :n]))
    assert sched.allocator.free_pages == sched.pool_pages


def test_gather_fallback_warns_once():
    """A family without native paged decode under the native default emits
    ONE RuntimeWarning and still serves through the gather bridge."""
    cfg = _family_cfg("dense")
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_new_tokens=4, stop_token=7)
    monkey = pytest.MonkeyPatch()
    try:
        import repro.models.dense as D
        monkey.setattr(D, "paged_decode_ok", lambda cfg: False)
        with pytest.warns(RuntimeWarning, match="gather bridge"):
            res = eng.generate({"tokens": jnp.asarray([[3, 4, 5, 6]])},
                               max_len=16, page_size=8)
        assert int(res["n_generated"][0]) >= 1
        assert eng._warned_gather_fallback
    finally:
        monkey.undo()


def test_ssm_family_refuses_paging():
    cfg = ModelConfig(name="t", family="ssm", n_layers=2, ssm_state=16,
                      ssm_headdim=16, ssm_chunk=16, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=64,
                      param_dtype="float32", compute_dtype="float32")
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_new_tokens=4)
    with pytest.raises(ValueError, match="paging does not apply"):
        ContinuousBatchingScheduler(eng, capacity=2, max_len=16, page_size=8)


# ---------------------------------------------------------------------------
# quantized pages: narrow pools widened in the gather (SVE extending loads)
# ---------------------------------------------------------------------------

def _quant_dtype_or_skip(name):
    try:
        return PG.resolve_page_dtype(name)
    except ValueError as e:                          # fp8-less jax build
        pytest.skip(str(e))


@pytest.mark.parametrize("page_dtype", ["int8", "fp8"])
def test_quantize_block_roundtrip_bounded(page_dtype):
    """quantize_block -> dequantize stays within the per-row absmax step:
    int8 rounds to absmax/127 steps (max error half a step), fp8 e4m3 keeps
    ~4 bits of relative precision.  All-zero rows decode to exactly zero."""
    dt = _quant_dtype_or_skip(page_dtype)
    rng = np.random.RandomState(0)
    v = rng.randn(5, 3, 16).astype(np.float32) * 4.0
    v[2, 1] = 0.0                                   # an all-zero row
    q, scale = PG.quantize_block(jnp.asarray(v), dt)
    assert q.dtype == dt and scale.shape == (5, 3)
    deq = np.asarray(PG.dequantize(q, scale))
    absmax = np.abs(v).max(-1, keepdims=True)
    tol = absmax * ((0.5 / 127.0) if page_dtype == "int8" else (1.0 / 16.0))
    assert (np.abs(deq - v) <= tol + 1e-7).all()
    np.testing.assert_array_equal(deq[2, 1], np.zeros(16, np.float32))
    assert float(scale[2, 1]) == 0.0


def test_gather_pages_scale_is_extending_load():
    """gather_pages(scale=...) widens narrow pool elements at the point of
    use: the view equals gathering an explicitly dequantized pool, and stays
    within quantization tolerance of the original f32 pages."""
    rng = np.random.RandomState(1)
    P, hkv, ps, d = 6, 2, 4, 8
    blocks = rng.randn(3, hkv, ps, d).astype(np.float32)
    ids = jnp.asarray([5, 0, 2], jnp.int32)
    pool = jnp.zeros((P, hkv, ps, d), jnp.int8)
    scale = jnp.zeros((P, hkv, ps), jnp.float32)
    pool, scale = PG.scatter_block_q(pool, scale, ids, jnp.asarray(blocks))
    table = jnp.asarray([[5, 0], [2, 5]], jnp.int32)
    view = PG.gather_pages(pool, table, scale=scale)
    assert view.dtype == jnp.float32
    np.testing.assert_array_equal(
        np.asarray(view),
        np.asarray(PG.gather_pages(PG.dequantize(pool, scale), table)))
    # lane 0 reads blocks 0 then 1; bounded by the absmax step per token row
    want = np.concatenate([blocks[0], blocks[1]], axis=1)
    tol = np.abs(want).max(-1, keepdims=True) * (0.5 / 127.0) + 1e-7
    assert (np.abs(np.asarray(view[0]) - want) <= tol).all()


def test_scatter_page_q_exact_single_token():
    """The decode-step quantizing write: per-(page, slot) scale granularity
    makes a single-token store quantize EXACTLY (same bytes+scale as
    quantize_block alone), with every other slot's bytes and scales
    untouched — no read-modify-write of neighbours."""
    rng = np.random.RandomState(2)
    P, hkv, ps, d = 5, 2, 4, 8
    pool = jnp.asarray(rng.randint(-127, 128, (P, hkv, ps, d)), jnp.int8)
    scale = jnp.asarray(rng.rand(P, hkv, ps).astype(np.float32))
    before_p, before_s = np.asarray(pool).copy(), np.asarray(scale).copy()
    vals = jnp.asarray(rng.randn(2, hkv, d).astype(np.float32))
    page_ids = jnp.asarray([3, 1], jnp.int32)
    offsets = jnp.asarray([2, 0], jnp.int32)
    pool2, scale2 = PG.scatter_page_q(pool, scale, page_ids, offsets, vals)
    q_want, s_want = PG.quantize_block(vals, jnp.int8)
    after_p, after_s = np.asarray(pool2).copy(), np.asarray(scale2).copy()
    for i in range(2):
        pid, off = int(page_ids[i]), int(offsets[i])
        np.testing.assert_array_equal(after_p[pid, :, off], q_want[i])
        np.testing.assert_array_equal(after_s[pid, :, off], s_want[i])
        after_p[pid, :, off] = before_p[pid, :, off]
        after_s[pid, :, off] = before_s[pid, :, off]
    np.testing.assert_array_equal(after_p, before_p)   # neighbours untouched
    np.testing.assert_array_equal(after_s, before_s)


@pytest.mark.parametrize("impl", ["naive", "xla", "kernel"])
def test_paged_flash_quantized_close_to_dense(impl):
    """Paged flash attention over int8 pools + scale pools stays within
    quantization tolerance of dense f32 flash — every impl widens the same
    narrow bytes through the same page walk."""
    rng = np.random.RandomState(3)
    B, Hq, Hkv, D, ps, npg, P = 2, 4, 2, 16, 8, 3, 9
    S = npg * ps
    kd = rng.randn(B, Hkv, S, D).astype(np.float32)
    vd = rng.randn(B, Hkv, S, D).astype(np.float32)
    q = jnp.asarray(rng.randn(B, Hq, 1, D).astype(np.float32))
    table = np.arange(B * npg, dtype=np.int32).reshape(B, npg)
    pool_k = jnp.zeros((P, Hkv, ps, D), jnp.int8)
    pool_v = jnp.zeros((P, Hkv, ps, D), jnp.int8)
    sc_k = jnp.zeros((P, Hkv, ps), jnp.float32)
    sc_v = jnp.zeros((P, Hkv, ps), jnp.float32)
    ids = jnp.arange(B * npg, dtype=jnp.int32)
    blk = lambda a: jnp.asarray(np.stack(
        [a[b, :, j * ps:(j + 1) * ps, :] for b in range(B)
         for j in range(npg)]))
    pool_k, sc_k = PG.scatter_block_q(pool_k, sc_k, ids, blk(kd))
    pool_v, sc_v = PG.scatter_block_q(pool_v, sc_v, ids, blk(vd))
    kv_lens = jnp.asarray([11, S], jnp.int32)
    q_off = kv_lens - 1
    ref = flash_attention(q, jnp.asarray(kd), jnp.asarray(vd),
                          kv_lens=kv_lens, q_offset=q_off, causal=True,
                          impl="xla")
    out = flash_attention(q, pool_k, pool_v, page_table=jnp.asarray(table),
                          kv_lens=kv_lens, q_offset=q_off, causal=True,
                          impl=impl, k_scale=sc_k, v_scale=sc_v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0.05, atol=0.05)


@pytest.mark.parametrize("family", ["dense", "moe", "hybrid", "encdec", "vlm"])
def test_quantized_native_decode_matches_gather_oracle(family):
    """Acceptance criterion: EVERY family decodes an int8 paged cache
    natively with token streams identical to the gather oracle, which
    dequantizes the same pool bytes into a dense view — the oracle bounds
    quantization error to exactly what quantize_block introduced, so any
    native/oracle divergence is a widening bug, not noise."""
    cfg = _family_cfg(family)
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(21)
    batch = _family_batch(cfg, rng, b=3, s=9)
    native = ServeEngine(cfg, params, max_new_tokens=6, stop_token=7,
                         page_dtype="int8")
    oracle = ServeEngine(cfg, params, max_new_tokens=6, stop_token=7,
                         paged_attn="gather", page_dtype="int8")
    paged = native.generate(batch, max_len=MAX_LEN, page_size=8)
    gathered = oracle.generate(batch, max_len=MAX_LEN, page_size=8)
    np.testing.assert_array_equal(np.asarray(paged["tokens"]),
                                  np.asarray(gathered["tokens"]))
    np.testing.assert_array_equal(np.asarray(paged["n_generated"]),
                                  np.asarray(gathered["n_generated"]))


def test_quantized_scheduler_matches_quantized_generate(dense_setup):
    """Streamed int8-paged requests (admission scatter_block_q writes +
    decode scatter_page_q writes, lane recycling, prefix sharing) produce
    the same tokens as fresh one-shot quantized generation — the scheduler
    introduces no quantization of its own."""
    cfg, _, params = dense_setup
    eng = ServeEngine(cfg, params, max_new_tokens=8, stop_token=7,
                      page_dtype="int8")
    rng = np.random.RandomState(22)
    prompts = [rng.randint(1, 64, rng.randint(4, 12)) for _ in range(8)]
    sched = ContinuousBatchingScheduler(eng, capacity=4, max_len=MAX_LEN,
                                        chunk=4, page_size=8)
    assert "k_pages_scale" in sched.cache            # scale pools allocated
    rids = [sched.submit(p) for p in prompts]
    results = sched.run()
    for rid, prompt in zip(rids, prompts):
        res = eng.generate({"tokens": jnp.asarray(prompt)[None, :]},
                           max_len=MAX_LEN, page_size=8)
        n = int(res["n_generated"][0])
        assert results[rid]["n_generated"] == n
        np.testing.assert_array_equal(results[rid]["tokens"],
                                      np.asarray(res["tokens"][0, :n]))
    assert sched.allocator.free_pages == sched.pool_pages
