"""Vector partitioning + scalarized sub-loops (paper §2.3.4–2.3.5, Fig. 6)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import partition as PT
from repro.core import predicate as P


def _random_list(rng, n_nodes, length):
    """Build a linked list of `length` nodes inside an `n_nodes` arena."""
    order = rng.permutation(n_nodes)[:length]
    nxt = np.full(n_nodes, -1, np.int32)
    for a, b in zip(order[:-1], order[1:]):
        nxt[a] = b
    vals = rng.integers(0, 1 << 30, n_nodes).astype(np.int64)
    return int(order[0]) if length else -1, nxt, vals, order


@given(st.integers(min_value=0, max_value=20), st.integers(min_value=1, max_value=16),
       st.integers(min_value=0, max_value=1000))
@settings(max_examples=40, deadline=None)
def test_linked_list_xor_fig6(length, vl, seed):
    """The paper's Fig. 6 split loop: serial pointer chase (pnext/cpy/ctermeq)
    + vectorized gather/eor + horizontal eorv, vs the scalar loop."""
    rng = np.random.default_rng(seed)
    head, nxt, vals, order = _random_list(rng, 32, length)
    nxt_j, vals_j = jnp.asarray(nxt), jnp.asarray(vals)

    # scalar reference
    want, p = 0, head
    while p != -1:
        want ^= int(vals[p])
        p = nxt[p]

    def outer(res_ptr):
        res, ptr = res_ptr

        def lane_step(state, p_lane, lane):
            cur, z = state
            z = P.cpy(p_lane, cur, z)
            return (nxt_j[cur], z), nxt_j[cur] >= 0

        (ptr, zvec), part = PT.serial_subloop(
            P.ptrue(vl), lane_step, (ptr, jnp.zeros(vl, jnp.int32)))
        gathered = jnp.take(vals_j, jnp.clip(zvec, 0, None), mode="fill", fill_value=0)
        from repro.core import reductions as R
        res = res ^ R.eorv(part, gathered)
        return res, ptr

    res, ptr = jnp.int64(0), jnp.asarray(head, jnp.int32)
    for _ in range((length // vl) + 2):     # python strip-mine loop (test only)
        if int(ptr) < 0:
            break
        res, ptr = outer((res, ptr))
    assert int(res) == want


def test_partitioned_while_batched_countdown():
    """Lanes count down from different starts; each lane must stop at 0 and
    keep its final value (merging semantics), like batched decode stop-tokens."""
    starts = jnp.array([3, 0, 5, 1], jnp.int32)

    def cond(state, p):
        return state > 0

    def body(state, p):
        return P.merging(p, state - 1, state)

    final, p_final = PT.partitioned_while(cond, body, starts, P.ptrue(4))
    assert final.tolist() == [0, 0, 0, 0]
    assert not bool(jnp.any(p_final))


def test_partitioned_while_respects_inactive_lanes():
    starts = jnp.array([2, 7], jnp.int32)
    p0 = jnp.array([True, False])

    def cond(state, p):
        return state > 0

    def body(state, p):
        return P.merging(p, state - 1, state)

    final, _ = PT.partitioned_while(cond, body, starts, p0)
    assert final.tolist() == [0, 7]


def test_brkpb_propagates_break_across_iterations():
    g = P.ptrue(4)
    # previous partition broke early (last lane inactive) => empty partition now
    prev = jnp.array([True, True, False, False])
    out = PT.brkpb(g, prev, jnp.zeros(4, bool))
    assert not bool(jnp.any(out))
    # previous partition full => normal brkb
    prev = P.ptrue(4)
    out = PT.brkpb(g, prev, jnp.array([False, False, True, False]))
    assert out.tolist() == [True, True, False, False]


def test_partitioned_while_is_jittable():
    def cond(state, p):
        return state < 10

    def body(state, p):
        return P.merging(p, state + 2, state)

    f = jax.jit(lambda s: PT.partitioned_while(cond, body, s, P.ptrue(3))[0])
    out = f(jnp.array([0, 5, 9], jnp.int32))
    assert out.tolist() == [10, 11, 11]
