"""Property tests for the SVE predicate algebra (paper §2.3 semantics)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import partition as PT
from repro.core import predicate as P

VL = st.integers(min_value=1, max_value=96)


def bitvec(data, vl):
    return np.array(data.draw(st.lists(st.booleans(), min_size=vl, max_size=vl)), bool)


@given(st.data())
@settings(max_examples=80, deadline=None)
def test_whilelt_matches_sequential_loop(data):
    vl = data.draw(VL)
    start = data.draw(st.integers(min_value=-10, max_value=200))
    limit = data.draw(st.integers(min_value=-10, max_value=200))
    p = np.array(P.whilelt(start, limit, vl))
    want = np.array([(start + i) < limit for i in range(vl)])
    assert (p == want).all()


@given(st.data())
@settings(max_examples=50, deadline=None)
def test_whilelt_nzcv_flags(data):
    """Table 1: N=first active, Z=none active, C=!last active."""
    vl = data.draw(VL)
    start = data.draw(st.integers(min_value=0, max_value=100))
    limit = data.draw(st.integers(min_value=0, max_value=100))
    p = P.whilelt(start, limit, vl)
    n, z, c = bool(P.first(p)), bool(P.none(p)), bool(P.not_last(p))
    assert n == (start < limit)
    assert z == (start >= limit)
    assert c == ((start + vl - 1) >= limit)


@given(st.data())
@settings(max_examples=80, deadline=None)
def test_brkb_brka_partition_laws(data):
    vl = data.draw(VL)
    g = bitvec(data, vl)
    c = bitvec(data, vl)
    brkb = np.array(PT.brkb(jnp.asarray(g), jnp.asarray(c)))
    brka = np.array(PT.brka(jnp.asarray(g), jnp.asarray(c)))
    # reference: sequential scan
    ref_b, ref_a, broken = [], [], False
    for i in range(vl):
        hit = g[i] and c[i]
        ref_b.append(g[i] and not broken and not hit)
        ref_a.append(g[i] and not broken)
        if hit:
            broken = True
    assert (brkb == np.array(ref_b)).all()
    assert (brka == np.array(ref_a)).all()
    # laws: brkb <= brka <= g ; brka \ brkb is at most one lane (the break lane)
    assert not (brkb & ~brka).any()
    assert not (brka & ~g).any()
    assert (brka & ~brkb).sum() <= 1


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_pnext_enumerates_active_lanes_in_order(data):
    vl = data.draw(VL)
    g = bitvec(data, vl)
    cur = P.pfalse(vl)
    seen = []
    for _ in range(int(g.sum()) + 1):
        cur = P.pnext(jnp.asarray(g), cur)
        if not bool(jnp.any(cur)):
            break
        assert int(P.cntp(cur)) == 1
        seen.append(int(jnp.argmax(cur)))
    assert seen == list(np.where(g)[0])


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_pfirst_plast(data):
    vl = data.draw(VL)
    g = bitvec(data, vl)
    pf = np.array(P.pfirst(jnp.asarray(g)))
    pl = np.array(P.plast(jnp.asarray(g)))
    if g.any():
        assert pf.sum() == 1 and np.argmax(pf) == np.where(g)[0][0]
        assert pl.sum() == 1 and np.argmax(pl) == np.where(g)[0][-1]
    else:
        assert not pf.any() and not pl.any()


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_accept_prefix_is_maximal_matching_prefix(data):
    vl = data.draw(VL)
    m = bitvec(data, vl)
    acc = np.array(PT.accept_prefix(jnp.asarray(m)))
    k = 0
    while k < vl and m[k]:
        k += 1
    want = np.zeros(vl, bool)
    want[:k] = True
    assert (acc == want).all()


@given(st.data())
@settings(max_examples=40, deadline=None)
def test_cntp_zeroing_merging(data):
    vl = data.draw(VL)
    g = bitvec(data, vl)
    x = np.arange(vl, dtype=np.float32) + 1
    assert int(P.cntp(jnp.asarray(g))) == int(g.sum())
    z = np.array(P.zeroing(jnp.asarray(g), jnp.asarray(x)))
    assert (z == np.where(g, x, 0)).all()
    old = -np.ones(vl, np.float32)
    mrg = np.array(P.merging(jnp.asarray(g), jnp.asarray(x), jnp.asarray(old)))
    assert (mrg == np.where(g, x, old)).all()
