"""Horizontal operations: ordered fadda, predicated reductions (paper §2.4)."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import reductions as R

floats = st.floats(min_value=-1e3, max_value=1e3, allow_nan=False, width=32)


@given(st.lists(floats, min_size=1, max_size=200), st.floats(min_value=-10, max_value=10))
@settings(max_examples=60, deadline=None)
def test_fadda_is_bit_identical_to_scalar_loop(xs, init):
    x = np.array(xs, np.float32)
    acc = np.float32(init)
    for v in x:
        acc = np.float32(acc + v)
    got = R.fadda(None, jnp.asarray(x), init=np.float32(init))
    assert np.float32(got) == acc


@given(st.lists(floats, min_size=1, max_size=200),
       st.sampled_from([4, 8, 16, 64, 128]))
@settings(max_examples=40, deadline=None)
def test_fadda_tiled_is_vl_invariant(xs, vl):
    """The paper's §3.3 requirement: the strictly-ordered reduction gives the
    SAME answer at every vector length — that is its whole purpose."""
    x = np.array(xs, np.float32)
    ref = np.float32(R.fadda(None, jnp.asarray(x)))
    got = np.float32(R.fadda_tiled(None, jnp.asarray(x), vl=vl))
    assert got == ref


@given(st.data())
@settings(max_examples=40, deadline=None)
def test_predicated_reductions_match_numpy(data):
    vl = data.draw(st.integers(min_value=1, max_value=64))
    x = np.array(data.draw(st.lists(st.integers(0, 1 << 20), min_size=vl, max_size=vl)),
                 np.int32)
    g = np.array(data.draw(st.lists(st.booleans(), min_size=vl, max_size=vl)), bool)
    xg, gj = jnp.asarray(x), jnp.asarray(g)
    want_xor = int(np.bitwise_xor.reduce(x[g])) if g.any() else 0
    want_or = int(np.bitwise_or.reduce(x[g])) if g.any() else 0
    assert int(R.eorv(gj, xg)) == want_xor
    assert int(R.orv(gj, xg)) == want_or
    got_max = int(R.smaxv(gj, xg))
    want_max = int(x[g].max()) if g.any() else np.iinfo(np.int32).min
    assert got_max == want_max


@given(st.lists(floats, min_size=1, max_size=300))
@settings(max_examples=40, deadline=None)
def test_pairwise_sum_close_and_deterministic(xs):
    x = np.array(xs, np.float32)
    a = float(R.pairwise_sum(jnp.asarray(x)))
    b = float(R.pairwise_sum(jnp.asarray(x)))
    assert a == b
    np.testing.assert_allclose(a, np.sum(x, dtype=np.float64), rtol=1e-4, atol=1e-2)


def test_fadda_batched_axis():
    x = np.random.RandomState(1).randn(5, 37).astype(np.float32)
    got = np.array(R.fadda(None, jnp.asarray(x)))
    want = np.zeros(5, np.float32)
    for r in range(5):
        acc = np.float32(0)
        for v in x[r]:
            acc = np.float32(acc + v)
        want[r] = acc
    assert (got == want).all()
