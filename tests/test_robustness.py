"""Overload resilience: priority preemption resumes BIT-EXACTLY (spill the
page chain + lane carries to host, re-admit later, tokens byte-identical to
an uninterrupted run) across all five families under the full combo stack;
cancellation / deadlines / load shedding return typed partial results; the
drain path leaks no pages on abort; the CRC'd swap store degrades corrupt
entries to cold prefills instead of serving wrong K/V; and a deterministic
chaos schedule (alloc failures + cancels + corruption) soaks it all."""

import functools

import jax
import numpy as np
import pytest

from repro.models import ModelConfig, get_model
from repro.obs import Obs, Tracer, validate_trace
from repro.serve import (
    ChaosConfig,
    ChaosMonkey,
    ContinuousBatchingScheduler,
    FinishReason,
    HostSwapStore,
    RequestRejected,
    SamplingParams,
    ServeEngine,
    burst_trace,
)

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=64, param_dtype="float32", compute_dtype="float32")

FAMILY_OVER = {
    "dense": {},
    "moe": dict(first_k_dense=1, n_experts=4, top_k=2, capacity_factor=4.0),
    "ssm": dict(ssm_state=16, ssm_headdim=16, ssm_chunk=4),
    "hybrid": dict(ssm_state=16, ssm_headdim=16, ssm_chunk=4,
                   shared_attn_period=2),
    "encdec": dict(n_enc_layers=2, n_dec_layers=2),
}
SRC_LEN = 12


@functools.lru_cache(maxsize=None)
def _mk_engine(family, seed=0):
    cfg = ModelConfig(name=f"t-{family}", family=family,
                      **{**BASE, **FAMILY_OVER[family]})
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed), cfg)
    return cfg, ServeEngine(cfg, params, max_new_tokens=6, stop_token=7)


def _sched(family, eng, **kw):
    if family == "encdec":
        kw.setdefault("src_len", SRC_LEN)
    return ContinuousBatchingScheduler(eng, **kw)


def _extras(rng, family):
    if family != "encdec":
        return None
    sl = rng.randint(2, SRC_LEN - 1)
    return {"src_emb": rng.randn(sl, BASE["d_model"]).astype(np.float32)}


def _overload_reqs(family, seed=0):
    """Two low-priority prompts at t=0 saturate a 4-page pool; a priority-5
    prompt due at t=1 then starves and must preempt a victim.  Every 3rd rid
    samples stochastically so a preempted lane's PRNG stream is exercised."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i, (arrival, priority) in enumerate([(0.0, 0), (0.0, 0), (1.0, 5)]):
        reqs.append(dict(tokens=rng.randint(1, 64, size=(9,)).astype(np.int32),
                         arrival=arrival, priority=priority,
                         extras=_extras(rng, family)))
    return reqs


def _submit_all(sched, reqs):
    rids = []
    for i, r in enumerate(reqs):
        sampling = (SamplingParams(temperature=0.8, top_p=0.9, seed=i,
                                   greedy=False) if i % 3 == 0 else None)
        rids.append(sched.submit(
            r["tokens"], arrival=r["arrival"],
            priority=r.get("priority", 0), extras=r.get("extras"),
            deadline=r.get("deadline"), ttft_deadline=r.get("ttft_deadline"),
            sampling=sampling))
    return rids


def _assert_identical(a, b, tag):
    assert set(a) == set(b), f"{tag}: rid sets differ"
    for rid in a:
        ta, tb = a[rid]["tokens"], b[rid]["tokens"]
        assert ta.dtype == tb.dtype, f"{tag}: rid {rid} dtype"
        assert ta.tobytes() == tb.tobytes(), \
            f"{tag}: rid {rid} tokens differ: {ta} vs {tb}"
        assert a[rid]["n_generated"] == b[rid]["n_generated"], \
            f"{tag}: rid {rid} n_generated"


# ---------------------------------------------------------------------------
# preempt/resume byte-identity — the tentpole invariant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", list(FAMILY_OVER))
def test_preempt_resume_byte_identity(family):
    """A starved 4-page pool under a priority-5 arrival forces preemption;
    the spilled/resumed request (and the victims) must serve tokens
    byte-identical to the same submissions on an ample pool that never
    preempts — under paged + chunked-prefill + compaction + overlap."""
    _, eng = _mk_engine(family)
    reqs = _overload_reqs(family)
    combo = dict(max_len=24, chunk=1, compact_threshold=0.5,
                 prefill_chunk=4, fused=True, overlap=True)
    if family == "ssm":
        # ssm caches carry no per-token KV state — paging does not apply;
        # starve on LANES instead of pages to force the dense spill path
        tight_kw = dict(capacity=2, **combo)
        ample_kw = dict(capacity=4, **combo)
    else:
        tight_kw = dict(capacity=4, page_size=8, pool_pages=4, **combo)
        ample_kw = dict(capacity=4, page_size=8, pool_pages=12, **combo)

    tight = _sched(family, eng, **tight_kw)
    _submit_all(tight, reqs)
    got = tight.run()
    assert tight.stats["preemptions"] > 0, \
        f"{family}: overload scenario never preempted"
    if family != "ssm":
        assert tight.stats["resume_page_ins"] > 0
        assert tight.allocator.live_pages == 0, f"{family}: leaked pages"

    ample = _sched(family, eng, **ample_kw)
    _submit_all(ample, reqs)
    ref = ample.run()
    assert ample.stats["preemptions"] == 0

    _assert_identical(got, ref, f"preempt[{family}]")
    reasons = {rid: r["finish_reason"] for rid, r in got.items()}
    assert FinishReason.PREEMPTED_RESUMED in reasons.values(), reasons
    for rid, r in ref.items():
        assert r["finish_reason"] == FinishReason.DONE


def test_equal_priority_never_preempts():
    """All-default-priority traffic must take the exact pre-existing path:
    starvation waits for pages instead of preempting a peer."""
    _, eng = _mk_engine("dense")
    reqs = _overload_reqs("dense")
    for r in reqs:
        r["priority"] = 0
    sched = _sched("dense", eng, capacity=4, max_len=24, chunk=1,
                   page_size=8, pool_pages=4, prefill_chunk=4,
                   fused=True, overlap=True)
    _submit_all(sched, reqs)
    res = sched.run()
    assert sched.stats["preemptions"] == 0
    assert all(r["finish_reason"] == FinishReason.DONE for r in res.values())
    assert sched.allocator.live_pages == 0


# ---------------------------------------------------------------------------
# cancellation
# ---------------------------------------------------------------------------

def test_cancel_lifecycle_stages():
    """Cancel hits every stage: queued (empty partial), mid-flight resident
    (partial tokens are a PREFIX of the uninterrupted stream), finished and
    unknown rids (False, results untouched)."""
    _, eng = _mk_engine("dense")
    rng = np.random.RandomState(3)
    prompts = [rng.randint(1, 64, size=(5,)).astype(np.int32)
               for _ in range(3)]

    ref = _sched("dense", eng, capacity=2, max_len=16, chunk=1)
    for p in prompts[:1]:
        ref.submit(p)
    full = ref.run()[0]["tokens"]

    sched = _sched("dense", eng, capacity=2, max_len=16, chunk=1)
    r0 = sched.submit(prompts[0])
    r1 = sched.submit(prompts[1])
    r2 = sched.submit(prompts[2], arrival=100.0)   # stays queued
    for _ in range(3):
        sched.step()
    assert sched.cancel(r0) is True                # resident, mid-flight
    part = sched.results[r0]
    assert part["finish_reason"] == FinishReason.CANCELLED
    assert 0 < part["n_generated"] < len(full)
    assert part["tokens"].tobytes() == \
        full[:part["n_generated"]].tobytes(), "partial is not a prefix"
    assert sched.cancel(r2) is True                # still queued
    assert sched.results[r2]["n_generated"] == 0
    res = sched.run()
    assert res[r1]["finish_reason"] == FinishReason.DONE
    assert sched.cancel(r1) is False               # already finished
    assert sched.cancel(999) is False              # never existed
    assert sched.stats["cancelled"] == 2


# ---------------------------------------------------------------------------
# deadlines + load shedding
# ---------------------------------------------------------------------------

def test_deadline_returns_partial():
    _, eng = _mk_engine("dense")
    rng = np.random.RandomState(4)
    prompt = rng.randint(1, 64, size=(5,)).astype(np.int32)

    ref = _sched("dense", eng, capacity=2, max_len=16, chunk=1)
    ref.submit(prompt)
    full = ref.run()[0]["tokens"]

    sched = _sched("dense", eng, capacity=2, max_len=16, chunk=1)
    rid = sched.submit(prompt, deadline=3.0)
    res = sched.run()
    assert res[rid]["finish_reason"] == FinishReason.DEADLINE
    assert sched.stats["deadline_misses"] == 1
    n = res[rid]["n_generated"]
    assert 0 < n < len(full)
    assert res[rid]["tokens"].tobytes() == full[:n].tobytes()


def test_infeasible_ttft_is_shed():
    """A request whose first token cannot land by its ttft_deadline (known
    from the observed decode-step histogram) is shed at admission."""
    _, eng = _mk_engine("dense")
    rng = np.random.RandomState(5)
    sched = _sched("dense", eng, capacity=2, max_len=16, chunk=1)
    sched.submit(rng.randint(1, 64, size=(5,)).astype(np.int32))
    late = sched.submit(rng.randint(1, 64, size=(5,)).astype(np.int32),
                        arrival=10.0, ttft_deadline=5.0)
    res = sched.run()
    assert res[late]["finish_reason"] == FinishReason.SHED
    assert res[late]["n_generated"] == 0
    assert sched.stats["shed"] == 1


def test_bounded_queue_sheds_overflow():
    _, eng = _mk_engine("dense")
    rng = np.random.RandomState(6)
    sched = _sched("dense", eng, capacity=1, max_len=16, chunk=1,
                   max_queue=2)
    rids = [sched.submit(rng.randint(1, 64, size=(4,)).astype(np.int32))
            for _ in range(4)]
    shed = [rid for rid in rids if rid in sched.results]
    assert len(shed) == 2 and sched.stats["shed"] == 2
    for rid in shed:
        assert sched.results[rid]["finish_reason"] == FinishReason.SHED
    res = sched.run()
    done = [rid for rid in rids if rid not in shed]
    assert all(res[rid]["finish_reason"] == FinishReason.DONE for rid in done)


# ---------------------------------------------------------------------------
# typed rejection
# ---------------------------------------------------------------------------

def test_request_rejected_is_typed_and_early():
    _, eng = _mk_engine("dense")
    sched = _sched("dense", eng, capacity=2, max_len=16, chunk=1)
    with pytest.raises(RequestRejected, match="exceeds lane capacity"):
        sched.submit(np.ones((30,), np.int32))
    assert issubclass(RequestRejected, ValueError)   # old callers still catch

    paged = _sched("dense", eng, capacity=2, max_len=16, chunk=1,
                   page_size=8, pool_pages=1, prefix_sharing=False)
    with pytest.raises(RequestRejected, match="fresh pages worst-case"):
        paged.submit(np.ones((10,), np.int32))
    assert not paged.queue and not paged.results     # rejected, not recorded


# ---------------------------------------------------------------------------
# drain on abort
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("exc", [KeyboardInterrupt, RuntimeError])
def test_run_drains_on_abort(exc):
    """An interrupt/crash mid-run still yields typed partial results for
    every in-flight request and releases every page (the leak-free drain
    contract); a real exception re-raises after draining."""
    _, eng = _mk_engine("dense")
    rng = np.random.RandomState(7)
    sched = _sched("dense", eng, capacity=2, max_len=24, chunk=1,
                   page_size=8, pool_pages=6, fused=True, overlap=True)
    rids = [sched.submit(rng.randint(1, 64, size=(6,)).astype(np.int32))
            for _ in range(4)]
    inner = sched._step_fused
    calls = {"n": 0}

    def bomb():
        calls["n"] += 1
        if calls["n"] == 3:
            raise exc("mid-run abort")
        inner()

    sched._step_fused = bomb
    if exc is KeyboardInterrupt:
        res = sched.run()
    else:
        with pytest.raises(RuntimeError):
            sched.run()
        res = sched.results
    assert set(res) == set(rids)
    for rid in rids:
        assert res[rid]["finish_reason"] in (FinishReason.CANCELLED,
                                             FinishReason.DONE)
    assert sched.allocator.live_pages == 0
    assert sched._stash is None


# ---------------------------------------------------------------------------
# CRC'd host swap
# ---------------------------------------------------------------------------

def test_host_swap_crc_detects_corruption():
    store = HostSwapStore(4)
    entry = {"k": np.arange(32, dtype=np.float32),
             "v": np.arange(32, dtype=np.float32) * 2}
    store.put(b"key", entry)
    assert store.get(b"key") is not None
    store._store[b"key"]["k"][3] += 1.0            # rot under the store
    assert store.get(b"key") is None               # detected, dropped
    assert store.checksum_failures == 1
    assert b"key" not in store


def test_swap_corruption_degrades_to_cold_prefill():
    """With EVERY swap insert corrupted, a session's swap hits all fail CRC
    and degrade to cold prefills — tokens stay identical to a clean run."""
    _, eng = _mk_engine("dense")
    rng = np.random.RandomState(9)
    prefix = rng.randint(1, 64, size=(8,)).astype(np.int32)
    prompts = [np.concatenate([prefix,
                               rng.randint(1, 64, size=(4,)).astype(np.int32)])
               for _ in range(3)]
    kw = dict(capacity=2, max_len=24, chunk=1, page_size=4, pool_pages=12,
              host_swap_pages=8, fused=True)

    clean = _sched("dense", eng, **kw)
    for i, p in enumerate(prompts):
        clean.submit(p, arrival=float(i * 12))     # gaps force eviction
    ref = clean.run()

    sched = _sched("dense", eng, **kw)
    monkey = ChaosMonkey(ChaosConfig(seed=1, swap_corrupt_rate=1.0))
    monkey.install(sched)
    for i, p in enumerate(prompts):
        sched.submit(p, arrival=float(i * 12))
    got = monkey.run(sched)

    assert monkey.corruptions > 0
    assert sched.stats["swap_checksum_failures"] > 0
    _assert_identical(got, ref, "swap-corrupt")
    assert sched.allocator.live_pages == 0


# ---------------------------------------------------------------------------
# deterministic chaos soak
# ---------------------------------------------------------------------------

def _chaos_reference():
    """Calm run of the canonical chaos trace (cached: rid -> tokens)."""
    _, eng = _mk_engine("dense")
    sched = _sched("dense", eng, capacity=3, max_len=24, chunk=1,
                   page_size=8, pool_pages=6, fused=True, overlap=True)
    for r in burst_trace(8, prompt_len=6, vocab=64, burst=3, gap=3.0,
                         seed=11, priority_of=lambda i: i % 2):
        sched.submit(r["tokens"], arrival=r["arrival"],
                     priority=r["priority"])
    return {rid: r["tokens"] for rid, r in sched.run().items()}


def _run_chaos(cfg: ChaosConfig):
    _, eng = _mk_engine("dense")
    sched = _sched("dense", eng, capacity=3, max_len=24, chunk=1,
                   page_size=8, pool_pages=6, fused=True, overlap=True)
    monkey = ChaosMonkey(cfg).install(sched)
    for r in burst_trace(8, prompt_len=6, vocab=64, burst=3, gap=3.0,
                         seed=11, priority_of=lambda i: i % 2):
        sched.submit(r["tokens"], arrival=r["arrival"],
                     priority=r["priority"])
    return sched, monkey, monkey.run(sched)


def _check_chaos_run(sched, res, ref):
    assert sched.allocator.live_pages == 0, "page leak after drain"
    assert sched.allocator.free_pages == sched.allocator.pool_pages
    assert set(res) == set(ref), "a request vanished without a result"
    for rid, r in res.items():
        reason = r["finish_reason"]
        assert reason in set(FinishReason), reason
        if reason in (FinishReason.DONE, FinishReason.PREEMPTED_RESUMED):
            assert r["tokens"].tobytes() == ref[rid].tobytes(), \
                f"rid {rid} served wrong tokens under chaos"


def test_chaos_replay_is_deterministic():
    """Same ChaosConfig → same injection schedule → byte-identical results:
    a failing soak replays exactly from its config."""
    cfg = ChaosConfig(seed=5, alloc_fail_rate=0.3, cancel_rate=0.1)
    s1, m1, r1 = _run_chaos(cfg)
    s2, m2, r2 = _run_chaos(cfg)
    assert (m1.alloc_failures, m1.cancels) == (m2.alloc_failures, m2.cancels)
    assert m1.alloc_failures > 0
    _assert_identical(r1, r2, "chaos-replay")
    assert {k: r1[k]["finish_reason"] for k in r1} == \
           {k: r2[k]["finish_reason"] for k in r2}


def test_chaos_soak_hypothesis():
    """Property soak: under ANY drawn fault schedule the scheduler drains
    leak-free, every request gets a typed result, and every request that
    reports done/preempted_resumed serves exactly the calm-run tokens."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    ref = _chaos_reference()

    @hyp.settings(max_examples=8, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(seed=st.integers(0, 2**16),
               alloc=st.sampled_from([0.0, 0.2, 0.5]),
               cancel=st.sampled_from([0.0, 0.1, 0.3]))
    def soak(seed, alloc, cancel):
        sched, _, res = _run_chaos(ChaosConfig(
            seed=seed, alloc_fail_rate=alloc, cancel_rate=cancel))
        _check_chaos_run(sched, res, ref)

    soak()


# ---------------------------------------------------------------------------
# observability: counters + lifecycle trace
# ---------------------------------------------------------------------------

def test_lifecycle_instants_trace_and_validate():
    """cancelled/preempted/resumed/deadline instants land inside their
    request's open span; the exported trace validates clean."""
    _, eng = _mk_engine("dense")
    obs = Obs(tracer=Tracer())
    reqs = _overload_reqs("dense")
    # the priority-5 arrival preempts one low-priority lane (3 tokens in);
    # the victim resumes only after the non-victim's pages free (~step 5)
    # and is still short of its budget when the clock passes this deadline
    # — a RESIDENT deadline retirement right after the resume.  The
    # non-victim finishes its 6-token budget at step 5, inside it.
    reqs[0]["deadline"] = reqs[1]["deadline"] = 6.0
    sched = _sched("dense", eng, capacity=4, max_len=24, chunk=1,
                   page_size=8, pool_pages=4, prefill_chunk=4,
                   fused=True, overlap=True, obs=obs)
    rids = _submit_all(sched, reqs)
    extra = sched.submit(np.arange(1, 10, dtype=np.int32))   # queued: full pool
    assert sched.cancel(extra) is True
    res = sched.run()
    obs.tracer.close()
    names = {e.get("name") for e in obs.tracer.events if e.get("ph") == "i"}
    assert {"preempted", "resumed", "cancelled", "deadline"} <= names, names
    assert validate_trace(obs.tracer.trace_events()) == []
    snap = obs.metrics.snapshot()
    for key in ("preemptions", "cancelled", "deadline_misses",
                "resume_page_ins", "shed", "swap_checksum_failures"):
        assert key in snap, f"counter {key} missing from snapshot"
    assert "ttft_steps_p50_steps" in snap
    low_prio = {res[rids[0]]["finish_reason"], res[rids[1]]["finish_reason"]}
    assert FinishReason.DEADLINE in low_prio, low_prio
    assert res[extra]["finish_reason"] == FinishReason.CANCELLED


def test_validate_trace_rejects_orphan_lifecycle_instant():
    ts = iter(range(10))
    events = [
        {"ph": "B", "ts": next(ts), "pid": 2, "tid": 0, "name": "req0"},
        {"ph": "E", "ts": next(ts), "pid": 2, "tid": 0, "name": "req0"},
        {"ph": "i", "ts": next(ts), "pid": 2, "tid": 0, "name": "cancelled",
         "s": "t"},
    ]
    errors = validate_trace(events)
    assert any("outside any open request span" in e for e in errors), errors
