"""Per-lane predicated sampling: greedy bit-identity, per-lane-seed stream
invariance (batch composition / admission order / compaction / paged vs
dense), processor masks vs the O(V) numpy reference, ordered top-p cumsum."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sample as S
from repro.core import reductions as R
from repro.models import ModelConfig, get_model
from repro.sample import numpy_ref as NR
from repro.sample import processors as PR
from repro.serve import ContinuousBatchingScheduler, ServeEngine

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=64, param_dtype="float32", compute_dtype="float32")
MAX_LEN = 24


def _mk(seed=0, **over):
    cfg = ModelConfig(name="t", family="dense", **{**BASE, **over})
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed), cfg)
    return cfg, model, params


# ---------------------------------------------------------------------------
# greedy fallback is bit-exact
# ---------------------------------------------------------------------------

def test_greedy_params_bit_identical_to_argmax_engine():
    """greedy=True and temperature<=0 both decode bit-identically to the
    default (argmax) engine — the merging-predicate select never perturbs
    greedy lanes."""
    cfg, _, params = _mk()
    eng = ServeEngine(cfg, params, max_new_tokens=6, stop_token=-999)
    prompts = jnp.asarray(np.random.RandomState(0).randint(1, 64, (3, 10)))
    ref = eng.generate({"tokens": prompts})
    for spec in (S.SamplingParams(greedy=True, seed=5),
                 S.SamplingParams(temperature=0.0, greedy=False, seed=5),
                 [S.SamplingParams(greedy=True),
                  S.SamplingParams(temperature=-1.0, greedy=False, seed=9),
                  None]):
        got = eng.generate({"tokens": prompts}, sampling=spec)
        np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                      np.asarray(ref["tokens"]))
        np.testing.assert_array_equal(np.asarray(got["n_generated"]),
                                      np.asarray(ref["n_generated"]))


def test_mixed_batch_greedy_lane_unperturbed():
    """A stochastic co-lane must not move a greedy lane by one bit."""
    cfg, _, params = _mk(seed=1)
    eng = ServeEngine(cfg, params, max_new_tokens=6, stop_token=-999)
    prompts = jnp.asarray(np.random.RandomState(1).randint(1, 64, (2, 8)))
    ref = eng.generate({"tokens": prompts})
    got = eng.generate({"tokens": prompts}, sampling=[
        None,
        S.SamplingParams(temperature=1.2, top_p=0.8, seed=3, greedy=False)])
    np.testing.assert_array_equal(np.asarray(got["tokens"][0]),
                                  np.asarray(ref["tokens"][0]))


def test_sampled_stream_seed_reproducible():
    cfg, _, params = _mk(seed=2)
    eng = ServeEngine(cfg, params, max_new_tokens=8, stop_token=-999)
    prompts = jnp.asarray(np.random.RandomState(2).randint(1, 64, (2, 8)))
    spec = [S.SamplingParams(temperature=0.8, top_p=0.9, seed=7, greedy=False),
            S.SamplingParams(temperature=1.0, top_k=10, seed=8, greedy=False)]
    a = eng.generate({"tokens": prompts}, sampling=spec)
    b = eng.generate({"tokens": prompts}, sampling=spec)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    # a different seed must (overwhelmingly) move the stream
    c = eng.generate({"tokens": prompts}, sampling=[
        S.SamplingParams(temperature=0.8, top_p=0.9, seed=99, greedy=False),
        spec[1]])
    assert np.asarray(c["tokens"][0]).tolist() != \
        np.asarray(a["tokens"][0]).tolist()
    np.testing.assert_array_equal(np.asarray(c["tokens"][1]),
                                  np.asarray(a["tokens"][1]))


# ---------------------------------------------------------------------------
# per-lane determinism: stream is a function of (seed, prompt, params) only
# ---------------------------------------------------------------------------

def _serve_one(eng, prompt, spec, *, co_prompts=(), co_specs=(),
               arrivals=None, capacity=4, compact_threshold=0.5,
               page_size=None, chunk=4):
    sched = ContinuousBatchingScheduler(
        eng, capacity=capacity, max_len=MAX_LEN, chunk=chunk,
        compact_threshold=compact_threshold, page_size=page_size)
    arrivals = arrivals or [0.0] * (1 + len(co_prompts))
    rid = sched.submit(prompt, sampling=spec, arrival=arrivals[0])
    for i, (p, s) in enumerate(zip(co_prompts, co_specs)):
        sched.submit(p, sampling=s, arrival=arrivals[1 + i])
    results = sched.run()
    return np.asarray(results[rid]["tokens"])


def test_sampled_stream_invariant_to_batch_composition():
    """Acceptance criterion: a request's sampled tokens are a function of
    (seed, prompt, params) only — co-scheduled traffic, admission order,
    compaction threshold, and paged vs dense cache must not move them."""
    cfg, _, params = _mk(seed=3)
    eng = ServeEngine(cfg, params, max_new_tokens=8, stop_token=7)
    rng = np.random.RandomState(3)
    prompt = rng.randint(1, 64, 9)
    spec = S.SamplingParams(temperature=0.9, top_p=0.9, top_k=32, seed=42,
                            greedy=False)

    alone = _serve_one(eng, prompt, spec)

    co = [rng.randint(1, 64, rng.randint(4, 12)) for _ in range(5)]
    co_specs = [S.SamplingParams(temperature=1.1, seed=100 + i, greedy=False)
                if i % 2 else None for i in range(5)]

    # different co-scheduled requests, same stream
    crowded = _serve_one(eng, prompt, spec, co_prompts=co, co_specs=co_specs)
    np.testing.assert_array_equal(alone, crowded)

    # staggered admission order (request arrives LAST) + aggressive
    # compaction churning the lane it ends up in
    late = _serve_one(eng, prompt, spec, co_prompts=co, co_specs=co_specs,
                      arrivals=[9.0, 0.0, 1.0, 2.0, 0.0, 3.0],
                      compact_threshold=0.9, capacity=3, chunk=2)
    np.testing.assert_array_equal(alone, late)

    # paged cache (gather view is bitwise the dense cache; the sampler state
    # must ride lane recycling identically)
    paged = _serve_one(eng, prompt, spec, co_prompts=co, co_specs=co_specs,
                       page_size=8)
    np.testing.assert_array_equal(alone, paged)


def test_scheduler_sampled_matches_oneshot_engine():
    """Scheduler-served sampled stream == ServeEngine.generate with the same
    spec (the continuous/one-shot bit-identity contract, stochastic leg)."""
    cfg, _, params = _mk(seed=4)
    eng = ServeEngine(cfg, params, max_new_tokens=8, stop_token=7)
    rng = np.random.RandomState(4)
    prompt = rng.randint(1, 64, 8)
    spec = S.SamplingParams(temperature=0.8, top_p=0.95, seed=11,
                            greedy=False)
    got = _serve_one(eng, prompt, spec)
    ref = eng.generate({"tokens": jnp.asarray(prompt)[None, :]},
                       max_len=MAX_LEN, sampling=[spec])
    n = int(ref["n_generated"][0])
    np.testing.assert_array_equal(got, np.asarray(ref["tokens"][0, :n]))


# ---------------------------------------------------------------------------
# processors vs the O(V) numpy reference
# ---------------------------------------------------------------------------

def _keep_mask_jax(logits, temperature, top_k, top_p, min_p):
    scaled = PR.temperature_scale(jnp.asarray(logits)[None, :],
                                  jnp.asarray([temperature], jnp.float32))
    keep = PR.top_k_pred(scaled, jnp.asarray([top_k], jnp.int32))
    keep &= PR.top_p_pred(scaled, jnp.asarray([top_p], jnp.float32))
    keep &= PR.min_p_pred(scaled, jnp.asarray([min_p], jnp.float32))
    return np.asarray(keep[0])


def test_masks_match_numpy_reference_seeded():
    rng = np.random.RandomState(0)
    v = 48
    for _ in range(60):
        logits = (rng.randn(v) * 2.5).astype(np.float32)
        k = int(rng.randint(0, v + 2))
        p = float(rng.uniform(0.05, 0.999))
        mp = float(rng.uniform(0.0, 0.4))
        t = float(rng.uniform(0.3, 2.0))
        got = _keep_mask_jax(logits, t, k, p, mp)
        ref = NR.ref_keep_mask(logits, temperature=t, top_k=k, top_p=p,
                               min_p=mp)
        assert (got == ref).all(), (k, p, mp, t, np.flatnonzero(got != ref))
        assert got[np.argmax(logits)]         # argmax always survives


def test_penalties_match_numpy_reference():
    rng = np.random.RandomState(1)
    v, t = 32, 10
    logits = (rng.randn(v) * 2).astype(np.float32)
    out_tokens = rng.randint(0, v, t).astype(np.int32)
    n_out = 6
    got = PR.apply_penalties(
        jnp.asarray(logits)[None, :], jnp.asarray(out_tokens)[None, :],
        jnp.asarray([n_out]), jnp.asarray([1.4], jnp.float32),
        jnp.asarray([0.3], jnp.float32))
    ref = NR.ref_penalised(logits, out_tokens[:n_out],
                           repetition_penalty=1.4, presence_penalty=0.3)
    np.testing.assert_allclose(np.asarray(got[0]), ref, rtol=1e-5, atol=1e-6)
    # stale buffer contents beyond n_out must NOT be penalised
    got2 = PR.apply_penalties(
        jnp.asarray(logits)[None, :],
        jnp.asarray(np.concatenate([out_tokens[:n_out],
                                    np.full(4, 5, np.int32)]))[None, :],
        jnp.asarray([n_out]), jnp.asarray([1.4], jnp.float32),
        jnp.asarray([0.3], jnp.float32))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(got2[0]))


def test_ban_and_stop_sequence_predicates():
    cfg, _, params = _mk(seed=6)
    eng0 = ServeEngine(cfg, params, max_new_tokens=6, stop_token=-999)
    prompts = jnp.asarray(np.random.RandomState(6).randint(1, 64, (1, 8)))
    ref = eng0.generate({"tokens": prompts})
    banned = int(ref["tokens"][0, 0])
    eng = ServeEngine(cfg, params, max_new_tokens=6, stop_token=-999,
                      banned_tokens=[banned])
    got = eng.generate({"tokens": prompts})
    assert banned not in np.asarray(got["tokens"][0]).tolist()
    # stop-sequence predicate: bigram (a, b) masks b exactly where the
    # last token is a
    pred = PR.stop_sequence_pred(8, jnp.asarray([3, 5]), [(3, 6), (5, 1)])
    want = np.ones((2, 8), bool)
    want[0, 6] = False
    want[1, 1] = False
    np.testing.assert_array_equal(np.asarray(pred), want)


def test_ban_applies_before_vocab_filters():
    """A banned argmax under top_k=1 must yield the best ALLOWED token —
    the ban predicate empties nucleus/top-k mass BEFORE filter generation,
    so the kept partition can never go empty (regression: the old order
    produced an all -inf row whose argmax silently returned token 0)."""
    rng = np.random.RandomState(9)
    v = 16
    logits = jnp.asarray((rng.randn(1, v) * 3).astype(np.float32))
    top = int(jnp.argmax(logits[0]))
    runner_up = int(jnp.argsort(-logits[0])[1])
    state = S.lane_state([S.SamplingParams(temperature=0.8, top_k=1, seed=0,
                                           greedy=False)], 1)
    ban = PR.ban_pred(v, [top])
    for _ in range(4):
        tok, state = S.sample(logits, state, ban=ban)
        assert int(tok[0]) == runner_up, (int(tok[0]), top, runner_up)


def test_sampled_tokens_respect_masks():
    """Every drawn token lies in the reference keep-set (predicates really
    govern the draw, not just the probabilities)."""
    rng = np.random.RandomState(2)
    v, b = 24, 16
    logits = jnp.asarray((rng.randn(b, v) * 2).astype(np.float32))
    spec = [S.SamplingParams(temperature=0.7, top_k=5, top_p=0.8,
                             seed=i, greedy=False) for i in range(b)]
    state = S.lane_state(spec, b)
    for _ in range(5):
        tok, state = S.sample(logits, state)
        for i in range(b):
            ref = NR.ref_keep_mask(np.asarray(logits[i]), temperature=0.7,
                                   top_k=5, top_p=0.8)
            assert ref[int(tok[i])], (i, int(tok[i]), np.flatnonzero(ref))


def test_fused_keep_pred_equals_individual_predicates():
    """The decode loop's fused keep_pred (one softmax + one argsort) is
    bit-identical to ANDing the three reference predicates."""
    rng = np.random.RandomState(8)
    b, v = 6, 40
    scaled = jnp.asarray((rng.randn(b, v) * 2).astype(np.float32))
    k = jnp.asarray(rng.randint(0, v + 2, b), jnp.int32)
    p = jnp.asarray(rng.uniform(0.05, 1.1, b), jnp.float32)
    mp = jnp.asarray(rng.uniform(0.0, 0.4, b), jnp.float32)
    fused = PR.keep_pred(scaled, k, p, mp)
    sep = (PR.top_k_pred(scaled, k) & PR.top_p_pred(scaled, p)
           & PR.min_p_pred(scaled, mp))
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(sep))


def test_degenerate_knobs_never_empty_the_partition():
    """top_p <= 0 and min_p > 1 must degrade to keeping the top-1 token —
    the kept partition can never go empty and silently emit token 0."""
    rng = np.random.RandomState(12)
    v = 12
    logits = jnp.asarray((rng.randn(1, v) * 2).astype(np.float32))
    top = int(jnp.argmax(logits[0]))
    assert top != 0                      # make token-0 fallout observable
    for spec in (S.SamplingParams(temperature=0.8, top_p=0.0, seed=0,
                                  greedy=False),
                 S.SamplingParams(temperature=0.8, min_p=1.5, seed=0,
                                  greedy=False)):
        state = S.lane_state([spec], 1)
        tok, state = S.sample(logits, state)
        assert int(tok[0]) == top, (spec, int(tok[0]), top)


def test_top_k_threshold_survives_softmax_underflow():
    """Distinct logits that underflow to equal float32 probs must still cut
    top-k at the true k-th largest LOGIT (the sort key is the scaled logit,
    never the collapsed probability)."""
    scaled = jnp.asarray([[0.0, -300.0, -200.0]], jnp.float32)
    got = np.asarray(PR.top_k_pred(scaled, jnp.asarray([2], jnp.int32))[0])
    np.testing.assert_array_equal(got, [True, False, True])


def test_default_sampling_decorrelates_requests():
    """Two identical prompts falling back to the engine default must NOT
    share a PRNG chain (seed is decorrelated by rid), yet each stream stays
    reproducible run-to-run."""
    cfg, _, params = _mk(seed=7)
    eng = ServeEngine(cfg, params, max_new_tokens=8, stop_token=-999,
                      default_sampling=S.SamplingParams(
                          temperature=1.0, seed=0, greedy=False))
    prompt = np.random.RandomState(7).randint(1, 64, 8)

    def serve_two():
        sched = ContinuousBatchingScheduler(eng, capacity=2, max_len=MAX_LEN,
                                            chunk=4)
        r0, r1 = sched.submit(prompt), sched.submit(prompt)
        res = sched.run()
        return (np.asarray(res[r0]["tokens"]), np.asarray(res[r1]["tokens"]))

    a0, a1 = serve_two()
    assert a0.tolist() != a1.tolist()          # decorrelated chains
    b0, b1 = serve_two()
    np.testing.assert_array_equal(a0, b0)      # still reproducible
    np.testing.assert_array_equal(a1, b1)
    # and the fallback bit-matches the one-shot engine's broadcast path
    # (fold_in(default key, submission index) on both sides)
    ref = eng.generate({"tokens": jnp.asarray(np.stack([prompt, prompt]))},
                       max_len=MAX_LEN)
    n0, n1 = int(ref["n_generated"][0]), int(ref["n_generated"][1])
    np.testing.assert_array_equal(a0, np.asarray(ref["tokens"][0, :n0]))
    np.testing.assert_array_equal(a1, np.asarray(ref["tokens"][1, :n1]))


# ---------------------------------------------------------------------------
# ordered top-p cumsum (fadda_scan)
# ---------------------------------------------------------------------------

def test_fadda_scan_matches_sequential_loop():
    rng = np.random.RandomState(3)
    x = (rng.randn(96) * 0.1).astype(np.float32)
    got = np.asarray(R.fadda_scan(None, jnp.asarray(x)))
    acc = np.float32(0.0)
    for i in range(96):
        acc = np.float32(acc + x[i])
        assert got[i] == acc, i          # bit-identical to the scalar loop
    # predicated: inactive elements contribute nothing
    p = jnp.asarray(rng.rand(96) < 0.5)
    gp = np.asarray(R.fadda_scan(p, jnp.asarray(x)))
    assert gp[-1] == np.asarray(R.fadda(p, jnp.asarray(x)))


def test_top_p_cutoff_bit_identical_to_scalar_accumulator():
    """The nucleus keep-set uses the EXCLUSIVE prefix mass taken directly
    from the shifted fadda_scan — bit-identical to a float32 scalar
    accumulation in the same (stable descending) order, never a re-rounded
    ``csum - p`` reconstruction."""
    rng = np.random.RandomState(5)
    for _ in range(25):
        v = int(rng.randint(4, 64))
        logits = (rng.randn(v) * 2).astype(np.float32)
        top_p = float(rng.uniform(0.1, 0.99))
        got = np.asarray(PR.top_p_pred(jnp.asarray(logits)[None, :],
                                       jnp.asarray([top_p], jnp.float32))[0])
        probs = np.asarray(jax.nn.softmax(jnp.asarray(logits)))  # f32, as jax
        order = np.argsort(-logits, kind="stable")
        keep = np.zeros((v,), bool)
        acc = np.float32(0.0)
        for idx in order:                        # the scalar fadda loop
            keep[idx] = acc < np.float32(top_p)
            acc = np.float32(acc + probs[idx])
        np.testing.assert_array_equal(got, keep, err_msg=str((v, top_p)))


def test_fadda_scan_final_equals_fadda():
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(5, 33).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(R.fadda_scan(None, x))[:, -1],
                                  np.asarray(R.fadda(None, x)))


# ---------------------------------------------------------------------------
# hypothesis property sweep (optional dep, importorskip per convention)
# ---------------------------------------------------------------------------

def test_masks_match_numpy_reference_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def run(data):
        v = data.draw(st.integers(min_value=2, max_value=40))
        logits = np.asarray(
            data.draw(st.lists(
                st.floats(min_value=-6, max_value=6, allow_nan=False,
                          width=32),
                min_size=v, max_size=v)), np.float32)
        k = data.draw(st.integers(min_value=0, max_value=v + 1))
        p = data.draw(st.floats(min_value=0.05, max_value=0.999, width=32))
        mp = data.draw(st.floats(min_value=0.0, max_value=0.5, width=32))
        t = data.draw(st.floats(min_value=0.25, max_value=3.0, width=32))
        got = _keep_mask_jax(logits, t, k, p, mp)
        ref = NR.ref_keep_mask(logits, temperature=t, top_k=k, top_p=p,
                               min_p=mp)
        if (got != ref).any():
            # tolerate float32-vs-float64 disagreement only at entries
            # sitting exactly on a threshold (probability mass within eps
            # of top_p, prob within eps of the min-p/top-k cut)
            probs = NR.ref_probs(logits, temperature=t)
            for idx in np.flatnonzero(got != ref):
                order = np.argsort(-probs, kind="stable")
                pos = int(np.flatnonzero(order == idx)[0])
                excl = float(probs[order[:pos]].sum())
                near_top_p = abs(excl - p) < 1e-5
                near_min_p = abs(probs[idx] - mp * probs.max()) < 1e-6
                x = logits / t if t > 0 else logits
                kth = np.sort(x)[::-1][min(max(k, 1), v) - 1]
                near_top_k = abs(x[idx] - kth) < 1e-5
                assert near_top_p or near_min_p or near_top_k, \
                    (idx, k, p, mp, t, logits.tolist())

    run()
