"""Continuous-batching scheduler: lane recycling, compaction, and the
bit-identity contract — tokens served through recycled/compacted lanes are
identical to serving the same requests in a fresh batch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, get_model
from repro.serve import ContinuousBatchingScheduler, ServeEngine
from repro.serve.speculative import speculative_decode

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=64, param_dtype="float32", compute_dtype="float32")
MAX_LEN = 24


def _mk(seed=0, **over):
    cfg = ModelConfig(name="t", family="dense", **{**BASE, **over})
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed), cfg)
    return cfg, model, params


def _fresh_reference(eng, prompt):
    """The request served alone in a fresh batch."""
    res = eng.generate({"tokens": jnp.asarray(prompt)[None, :]},
                       max_len=MAX_LEN)
    n = int(res["n_generated"][0])
    return np.asarray(res["tokens"][0, :n]), n


def test_streamed_requests_bit_identical_to_fresh_batches():
    cfg, _, params = _mk()
    eng = ServeEngine(cfg, params, max_new_tokens=8, stop_token=7)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, 64, rng.randint(4, 12)) for _ in range(10)]
    sched = ContinuousBatchingScheduler(eng, capacity=4, max_len=MAX_LEN,
                                        chunk=4, compact_threshold=0.5)
    rids = [sched.submit(p) for p in prompts]
    results = sched.run()
    assert sorted(results) == sorted(rids)
    for rid, prompt in zip(rids, prompts):
        want, n = _fresh_reference(eng, prompt)
        got = results[rid]
        assert got["n_generated"] == n
        np.testing.assert_array_equal(got["tokens"], want)


def test_compaction_admits_into_recycled_lanes_bit_identical():
    """Acceptance criterion: a batch with 75% finished lanes compacts, admits
    queued requests into the freed lanes, and the admitted requests' tokens
    are bit-identical to serving them in a fresh batch."""
    cfg, _, params = _mk(seed=1)
    eng = ServeEngine(cfg, params, max_new_tokens=12, stop_token=7)
    rng = np.random.RandomState(1)

    # wave 1: 4 requests; give 3 of them a 1-token budget so 75% of lanes
    # finish after the first chunk while lane 'survivor' keeps decoding
    wave1 = [rng.randint(1, 64, rng.randint(4, 10)) for _ in range(4)]
    # wave 2: queued requests that arrive after wave 1 is in flight
    wave2 = [rng.randint(1, 64, rng.randint(4, 10)) for _ in range(3)]

    sched = ContinuousBatchingScheduler(eng, capacity=4, max_len=MAX_LEN,
                                        chunk=2, compact_threshold=0.75)
    rids1 = [sched.submit(p, max_new_tokens=(12 if i == 2 else 1))
             for i, p in enumerate(wave1)]
    rids2 = [sched.submit(p, arrival=2.0) for p in wave2]

    results = sched.run()
    assert sched.stats["compactions"] >= 1      # occupancy dropped below 75%
    for rid, prompt in zip(rids1 + rids2, wave1 + wave2):
        got = results[rid]
        ref = eng.generate({"tokens": jnp.asarray(prompt)[None, :]},
                           max_len=MAX_LEN)
        budget = 1 if (rid in rids1 and rid != rids1[2]) else 12
        n_ref = min(int(ref["n_generated"][0]), budget)
        want = np.asarray(ref["tokens"][0, :n_ref])
        assert got["n_generated"] == n_ref, (rid, got, want)
        np.testing.assert_array_equal(got["tokens"], want)


def test_scheduler_respects_arrival_times():
    cfg, _, params = _mk(seed=2)
    eng = ServeEngine(cfg, params, max_new_tokens=4, stop_token=-1)
    rng = np.random.RandomState(2)
    sched = ContinuousBatchingScheduler(eng, capacity=2, max_len=MAX_LEN,
                                        chunk=2)
    early = sched.submit(rng.randint(1, 64, 5), arrival=0.0)
    late = sched.submit(rng.randint(1, 64, 5), arrival=100.0)
    results = sched.run()
    assert results[early]["finished_at"] < results[late]["finished_at"]
    # the late request was never admitted before its arrival
    assert results[late]["finished_at"] > 100.0


def test_due_request_not_blocked_by_future_head():
    """A far-future arrival at the queue head must not starve due requests
    behind it (FIFO applies among the due only)."""
    cfg, _, params = _mk(seed=5)
    eng = ServeEngine(cfg, params, max_new_tokens=4, stop_token=-1)
    rng = np.random.RandomState(5)
    sched = ContinuousBatchingScheduler(eng, capacity=2, max_len=MAX_LEN,
                                        chunk=2)
    future = sched.submit(rng.randint(1, 64, 5), arrival=1000.0)
    due = sched.submit(rng.randint(1, 64, 5), arrival=0.0)
    results = sched.run()
    assert results[due]["finished_at"] < 1000.0
    assert results[future]["finished_at"] > 1000.0


def _serve_all(eng, prompts, budgets, arrivals, **kw):
    sched = ContinuousBatchingScheduler(eng, capacity=4, max_len=MAX_LEN,
                                        chunk=4, compact_threshold=0.5, **kw)
    rids = [sched.submit(p, max_new_tokens=b, arrival=a)
            for p, b, a in zip(prompts, budgets, arrivals)]
    results = sched.run()
    return {r: (results[r]["tokens"].tolist(), results[r]["n_generated"])
            for r in rids}, sched


def test_chunked_prefill_bit_identical_to_whole_prefill():
    """Acceptance criterion: splitting admission prefill into chunks
    interleaved with decode rounds changes NOTHING about the served tokens —
    ``pos0`` suffix-prefill numerics depend only on absolute positions and
    the cache extent, so chunk boundaries are invisible.  Covers the dense
    and the paged scheduler, ragged budgets and staggered arrivals."""
    cfg, _, params = _mk(seed=3)
    eng = ServeEngine(cfg, params, max_new_tokens=8, stop_token=7)
    rng = np.random.RandomState(3)
    prompts = [rng.randint(1, 64, rng.randint(4, 16)) for _ in range(10)]
    budgets = [int(rng.randint(2, 9)) for _ in prompts]
    arrivals = [float(i) * 0.7 for i in range(len(prompts))]

    whole, _ = _serve_all(eng, prompts, budgets, arrivals)
    for chunk in (3, 5):
        got, sched = _serve_all(eng, prompts, budgets, arrivals,
                                prefill_chunk=chunk)
        assert sched.stats["prefill_chunks"] > 0     # chunking actually ran
        assert got == whole
        pg, sched_p = _serve_all(eng, prompts, budgets, arrivals,
                                 page_size=8, prefill_chunk=chunk)
        assert sched_p.stats["prefill_chunks"] > 0
        assert pg == whole
        assert sched_p.allocator.free_pages == sched_p.pool_pages


def test_chunked_prefill_moe_family():
    """MoE chunked prefill (capacity sized so nothing drops) serves the same
    tokens as whole prefill."""
    cfg = ModelConfig(name="t", family="moe", first_k_dense=1, n_experts=4,
                      top_k=2, capacity_factor=4.0, **BASE)
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_new_tokens=6, stop_token=7)
    rng = np.random.RandomState(4)
    prompts = [rng.randint(1, 64, rng.randint(4, 14)) for _ in range(6)]
    budgets = [6] * len(prompts)
    arrivals = [0.0] * len(prompts)
    whole, _ = _serve_all(eng, prompts, budgets, arrivals)
    got, sched = _serve_all(eng, prompts, budgets, arrivals, prefill_chunk=4)
    assert sched.stats["prefill_chunks"] > 0
    assert got == whole


def test_chunked_prefill_granularity_enforced_for_ssm():
    """ssm/hybrid resume the SSD scan across chunks, so chunk boundaries
    must sit on the ``ssm_chunk`` grid: misaligned sizes are refused loudly,
    aligned ones serve bit-identically to whole-prompt prefill."""
    cfg = ModelConfig(name="t", family="ssm", ssm_state=16, ssm_headdim=16,
                      ssm_chunk=4, **BASE)
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, max_new_tokens=6, stop_token=7)
    with pytest.raises(ValueError, match="multiple of"):
        ContinuousBatchingScheduler(eng, capacity=2, max_len=16,
                                    prefill_chunk=6)
    rng = np.random.RandomState(6)
    prompts = [rng.randint(1, 64, rng.randint(4, 16)) for _ in range(6)]
    budgets = [int(rng.randint(2, 7)) for _ in prompts]
    arrivals = [float(i) * 0.7 for i in range(len(prompts))]
    whole, _ = _serve_all(eng, prompts, budgets, arrivals)
    got, sched = _serve_all(eng, prompts, budgets, arrivals, prefill_chunk=4)
    assert sched.stats["prefill_chunks"] > 0
    assert got == whole


def test_submit_rejects_oversized_prompt():
    cfg, _, params = _mk(seed=5)
    eng = ServeEngine(cfg, params, max_new_tokens=4, stop_token=-1)
    sched = ContinuousBatchingScheduler(eng, capacity=2, max_len=MAX_LEN)
    with pytest.raises(ValueError, match="exceeds lane capacity"):
        sched.submit(np.arange(MAX_LEN + 1))


def test_immediate_stop_lane_recycles():
    """A request whose FIRST sampled token is the stop token must still
    complete (n_generated == 1) and free its lane."""
    cfg, _, params = _mk(seed=3)
    # probe what the first token of some prompt is, then use it as stop
    rng = np.random.RandomState(3)
    prompt = rng.randint(1, 64, 6)
    eng0 = ServeEngine(cfg, params, max_new_tokens=4, stop_token=-1)
    probe = eng0.generate({"tokens": jnp.asarray(prompt)[None, :]},
                          max_len=MAX_LEN)
    stop = int(probe["tokens"][0, 0])
    eng = ServeEngine(cfg, params, max_new_tokens=4, stop_token=stop)
    sched = ContinuousBatchingScheduler(eng, capacity=2, max_len=MAX_LEN)
    rid = sched.submit(prompt)
    other = sched.submit(rng.randint(1, 64, 6))
    results = sched.run()
    assert results[rid]["n_generated"] == 1
    assert results[rid]["tokens"].tolist() == [stop]
    assert other in results


# ---------------------------------------------------------------------------
# batched speculative decoding composes with the partition algebra
# ---------------------------------------------------------------------------

def _greedy_reference(model, params, cfg, prompt, n):
    toks = prompt
    out = []
    for _ in range(n):
        logits, _ = model.train_logits(params, cfg, {"tokens": toks})
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        out.append(int(nxt[0]))
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    return out


@pytest.mark.parametrize("k_draft", [2, 3])
def test_batched_speculative_matches_per_lane_greedy(k_draft):
    """accept_prefix composes with lane batching: every lane of the batched
    speculative path equals that lane's target-alone greedy decode."""
    tcfg, tmodel, tparams = _mk(seed=4)
    dcfg, _, _ = _mk(seed=0, n_layers=1, d_model=32, d_ff=64,
                     n_heads=2, n_kv_heads=1)
    dparams = get_model(dcfg).init(jax.random.PRNGKey(5), dcfg)[0]
    rng = np.random.RandomState(4)
    b, s, n = 3, 8, 9
    prompts = jnp.asarray(rng.randint(1, 64, (b, s)))
    lens = jnp.asarray([8, 5, 7], jnp.int32)
    got, stats = speculative_decode(tcfg, tparams, dcfg, dparams, prompts,
                                    n_tokens=n, k_draft=k_draft, lens=lens)
    assert got.shape == (b, n)
    for row in range(b):
        ref = _greedy_reference(tmodel, tparams, tcfg,
                                prompts[row:row + 1, :int(lens[row])], n)
        assert got[row].tolist() == ref, (row, got[row].tolist(), ref, stats)


def test_batched_speculative_with_stop_token():
    """accept_prefix ∘ brka(stop): committed windows truncate at the stop
    token per lane, and dead lanes stop consuming budget."""
    tcfg, tmodel, tparams = _mk(seed=6)
    rng = np.random.RandomState(6)
    prompts = jnp.asarray(rng.randint(1, 64, (2, 6)))
    # perfect draft (same model) => acceptance is full; find a token the
    # first lane emits so we can use it as a stop token
    probe, _ = speculative_decode(tcfg, tparams, tcfg, tparams, prompts,
                                  n_tokens=6, k_draft=2)
    stop = int(probe[0, 2])
    got, stats = speculative_decode(tcfg, tparams, tcfg, tparams, prompts,
                                    n_tokens=6, k_draft=2, stop_token=stop)
    n0 = int(stats["n_generated"][0])
    # lane 0 halts at its stop token; committed prefix is unchanged
    assert stop in got[0, :n0].tolist()
    assert got[0, :n0].tolist() == probe[0, :n0].tolist()
    first_stop = probe[0].tolist().index(stop)
    assert n0 == first_stop + 1


# ---------------------------------------------------------------------------
# host-swap eviction tier: the prefix cache as a cross-request session cache
# ---------------------------------------------------------------------------

def test_multi_turn_session_page_in_byte_identical():
    """Acceptance criterion: turn 2 of a conversation arrives after turn 1's
    lanes retired (its shared-prefix pages spilled to the host store), pages
    the prefix back in, and decodes tokens BYTE-IDENTICAL to a scheduler
    that never swapped — page-in restores the exact pool bytes."""
    cfg, _, params = _mk()
    eng = ServeEngine(cfg, params, max_new_tokens=6, stop_token=7)
    rng = np.random.RandomState(30)
    turn1 = [rng.randint(1, 64, 9) for _ in range(3)]
    turn2 = [np.concatenate([p, rng.randint(1, 64, 4)]) for p in turn1]

    def serve_two_waves(host_swap_pages):
        sched = ContinuousBatchingScheduler(
            eng, capacity=4, max_len=MAX_LEN, chunk=4, page_size=4,
            host_swap_pages=host_swap_pages)
        for p in turn1:
            sched.submit(p)
        sched.run()                                  # wave 1 fully retires
        rids = [sched.submit(p) for p in turn2]
        res = sched.run()
        toks = [res[r]["tokens"].tolist() for r in rids]
        return sched, toks

    warm_sched, warm = serve_two_waves(host_swap_pages=64)
    cold_sched, cold = serve_two_waves(host_swap_pages=None)
    assert warm == cold                              # byte-identical greedy
    st = warm_sched.stats
    assert st["session_hits"] > 0                    # cross-request hits
    assert st["swap_out_pages"] > 0 and st["swap_in_pages"] > 0
    assert st["session_hit_tokens"] >= st["session_hits"] * 4
    assert cold_sched.stats["session_hits"] == 0
    # drained: every page back, nothing resident survives in the index
    assert warm_sched.allocator.free_pages == warm_sched.pool_pages
    assert (warm_sched.allocator.refcount == 0).all()
    assert len(warm_sched.prefix_index) == 0
    assert len(warm_sched.host_swap) <= 64


def test_host_swap_requires_paging_and_prefix_sharing():
    cfg, _, params = _mk()
    eng = ServeEngine(cfg, params, max_new_tokens=4)
    with pytest.raises(ValueError, match="host_swap_pages"):
        ContinuousBatchingScheduler(eng, capacity=2, max_len=16,
                                    host_swap_pages=8)


def test_session_results_unperturbed_by_swap_tier():
    """The swap tier must be invisible to correctness: a ragged mixed trace
    (shared prefixes, natural stops, lane recycling) served WITH the tier
    matches per-request fresh dense references bit-exactly, and the LRU
    store respects its capacity while evicting."""
    cfg, _, params = _mk()
    eng = ServeEngine(cfg, params, max_new_tokens=6, stop_token=7)
    rng = np.random.RandomState(31)
    common = rng.randint(1, 64, 5)
    prompts = [np.concatenate([common, rng.randint(1, 64, rng.randint(2, 6))])
               if i % 2 == 0 else rng.randint(1, 64, rng.randint(4, 10))
               for i in range(10)]
    sched = ContinuousBatchingScheduler(eng, capacity=3, max_len=MAX_LEN,
                                        chunk=3, page_size=4,
                                        host_swap_pages=2)
    rids = [sched.submit(p, arrival=float(i)) for i, p in enumerate(prompts)]
    results = sched.run()
    for rid, prompt in zip(rids, prompts):
        want, n = _fresh_reference(eng, prompt)
        assert results[rid]["n_generated"] == n
        np.testing.assert_array_equal(results[rid]["tokens"], want)
    assert len(sched.host_swap) <= 2                 # capacity respected
    assert sched.stats["swap_out_pages"] > 0
    assert sched.allocator.free_pages == sched.pool_pages
