"""Serving engine: vector-partitioned early exit + speculative decoding
(FFR acceptance) — greedy-equivalence is asserted exactly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, get_model
from repro.serve import ServeEngine, speculative_decode

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=64, param_dtype="float32", compute_dtype="float32")


def _mk(cfg, seed=0):
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed), cfg)
    return model, params


def _greedy_reference(model, params, cfg, prompt, n):
    """Generate n tokens by repeatedly re-running the full forward."""
    toks = prompt
    out = []
    for _ in range(n):
        logits, _ = model.train_logits(params, cfg, {"tokens": toks})
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        out.append(int(nxt[0]))
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    return out


def test_engine_matches_full_forward_greedy():
    cfg = ModelConfig(name="t", family="dense", **BASE)
    model, params = _mk(cfg)
    prompt = jnp.asarray(np.random.RandomState(0).randint(1, 64, (1, 12)))
    eng = ServeEngine(cfg, params, max_new_tokens=6, stop_token=-999)
    res = eng.generate({"tokens": prompt})
    want = _greedy_reference(model, params, cfg, prompt, 6)
    assert res["tokens"][0].tolist() == want


def test_engine_ragged_batch_and_early_exit():
    cfg = ModelConfig(name="t", family="dense", **BASE)
    model, params = _mk(cfg, seed=1)
    rng = np.random.RandomState(1)
    prompts = jnp.asarray(rng.randint(1, 64, (3, 10)))
    lens = jnp.array([10, 4, 7], jnp.int32)
    # find what token row 1 generates first, use it as the stop token so that
    # lane 1 exits early while others continue
    eng0 = ServeEngine(cfg, params, max_new_tokens=4, stop_token=-999)
    probe = eng0.generate({"tokens": prompts, "lens": lens})
    stop = int(probe["tokens"][1, 0])
    eng = ServeEngine(cfg, params, max_new_tokens=4, stop_token=stop)
    res = eng.generate({"tokens": prompts, "lens": lens})
    assert not bool(res["active"][1])            # lane 1 exited
    # ragged rows must equal their unpadded reference
    row = 1
    ref = _greedy_reference(model, params, cfg, prompts[row:row + 1, :int(lens[row])], 1)
    assert int(res["tokens"][row, 0]) == ref[0]


@pytest.mark.parametrize("k_draft", [2, 4])
def test_speculative_equals_target_greedy(k_draft):
    tcfg = ModelConfig(name="target", family="dense", **BASE)
    dcfg = ModelConfig(name="draft", family="dense",
                       **{**BASE, "n_layers": 1, "d_model": 32, "d_ff": 64,
                          "n_heads": 2, "n_kv_heads": 1})
    tmodel, tparams = _mk(tcfg, seed=2)
    _, dparams = _mk(dcfg, seed=3)
    prompt = jnp.asarray(np.random.RandomState(2).randint(1, 64, (1, 8)))
    n = 10
    got, stats = speculative_decode(tcfg, tparams, dcfg, dparams, prompt,
                                    n_tokens=n, k_draft=k_draft)
    want = _greedy_reference(tmodel, tparams, tcfg, prompt, n)
    assert got.tolist() == want, (got.tolist(), want, stats)
    assert 0.0 <= stats["mean_accepted"] <= k_draft


def test_speculative_with_good_draft_accepts_more():
    """Draft == target => every speculation accepted (FFR never faults)."""
    tcfg = ModelConfig(name="target", family="dense", **BASE)
    _, tparams = _mk(tcfg, seed=4)
    prompt = jnp.asarray(np.random.RandomState(3).randint(1, 64, (1, 6)))
    got, stats = speculative_decode(tcfg, tparams, tcfg, tparams, prompt,
                                    n_tokens=8, k_draft=3)
    assert stats["mean_accepted"] == 3.0
    tmodel = get_model(tcfg)
    want = _greedy_reference(tmodel, tparams, tcfg, prompt, 8)
    assert got.tolist() == want
