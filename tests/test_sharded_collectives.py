"""Collective-traffic gate for the mesh-sharded decode step: lower the serve
engine's decode program on a forced 8-device (data=4, model=2) mesh and count
the collectives XLA actually emitted (``benchmarks.hlo_analysis``).

The decode step must stay ACTIVATION-shaped: serving runs column-parallel
TP (contractions whole, small activation gathers before the row-parallel
dots — see dist.sharding.SERVE_RULES), so all-reduces are bounded by one
per attention layer plus a constant sampling overhead, and NO collective
may move anything approaching a full KV page pool.  The second gate is the
one with teeth — a
missing logical-axis rule makes GSPMD silently materialize replicated
operands by all-gathering a weight or a pool, which "works" (tokens stay
byte-identical) while multiplying per-step network traffic.  Counting ops in
the compiled HLO catches that regression at test time instead of in a fleet
profile.

Subprocess test: the forced device count must never leak into other tests.
"""

import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, ".")
import jax
from benchmarks.hlo_analysis import analyze
from repro.models import ModelConfig, get_model
from repro.serve import ContinuousBatchingScheduler, ServeEngine
from repro.launch.mesh import make_mesh

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=64, param_dtype="float32", compute_dtype="float32")
cfg = ModelConfig(name="gate", family="dense", **BASE)
model = get_model(cfg)
params, _ = model.init(jax.random.PRNGKey(0), cfg)
# model=2 divides n_kv_heads=2, so the page pools are GENUINELY kv-head
# sharded here (on a model=4 mesh they would replicate via the divisibility
# fallback and the pool-gather gate below would be vacuous)
mesh = make_mesh((4, 2), ("data", "model"))
eng = ServeEngine(cfg, params, max_new_tokens=6, stop_token=7, mesh=mesh)
sched = ContinuousBatchingScheduler(eng, capacity=4, max_len=24, chunk=3,
                                    compact_threshold=0.5, page_size=4,
                                    pool_pages=14)
rep = analyze(eng._decode_chunk_serve.lower(
    eng.params, sched.cache, sched.out_buf, sched.tok, sched.p,
    sched.n_gen, sched.budget, sched.sstate,
    n_steps=1, stochastic=False).compile().as_text())
counts = rep["collective_counts"]
maxes = rep["collective_max_bytes"]
print("counts:", counts)
print("max bytes:", maxes)

# gate 1: no per-layer reduction creep.  Serving TP is column-parallel
# (SERVE_RULES): layer dots run whole after small activation gathers, so
# the only all-reduces left are sampling/head overhead.  Bound: one per
# attention layer + 4 slack.  2 layers -> cap 8; measured today: 2 total.
n_layers = cfg.n_layers
ar = counts.get("all-reduce", 0)
assert ar <= n_layers + 4, (
    f"decode step emits {ar} all-reduces for {n_layers} layers — more than "
    f"one per attention layer plus head overhead; a split-contraction "
    f"resolution has crept into the column-parallel serve path")

# gate 2: nothing resembling a pool (or a weight matrix) crosses the wire.
# The smallest \"bad\" collective is a full page pool all-gather; gate at
# half a pool so even a single-pool gather (15360 B here) trips it.
# Measured today: max single collective is 512 B (a gathered activation
# row), ~4 KB total per step.
pool_bytes = min(v.nbytes for k, v in sched.cache.items()
                 if k.endswith("_pages"))
worst = max(maxes.values(), default=0.0)
assert worst < pool_bytes / 2, (
    f"largest single collective moves {worst} B — vs {pool_bytes} B for a "
    f"full KV page pool; something (pool or weight) is being all-gathered "
    f"on the decode hot path")
print("collective gate OK")
"""


def test_sharded_decode_collective_budget():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=580,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            # force CPU: without this jax probes for
                            # accelerator plugins and can hang on
                            # network lookups in the bare subprocess
                            "JAX_PLATFORMS": "cpu",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "collective gate OK" in r.stdout
