"""Mesh-sharded serving: the fused serve program under a forced 8-device CPU
mesh (model=2 x data=4) serves BYTE-IDENTICAL tokens to the unsharded fused
loop — for all five families, and for the full paged + prefix-sharing +
chunked-prefill + compaction combination — with the same per-round dispatch
count (no per-token host sync regression).  A second test lowers the sharded
decode step and gates its collective count per attention layer (catches an
accidental all-gather of a full page pool).

Subprocess tests: the forced device count must never leak into other tests.
"""

import subprocess
import sys

import pytest

_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
        # force CPU: without this jax probes for accelerator plugins and
        # can hang on network lookups in the bare subprocess
        "JAX_PLATFORMS": "cpu", "HOME": "/root"}

_PRELUDE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from repro.models import ModelConfig, get_model
from repro.serve import (ContinuousBatchingScheduler, SamplingParams,
                         ServeEngine)
from repro.launch.mesh import make_mesh

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=64, param_dtype="float32", compute_dtype="float32")
FAMILY_OVER = {
    "dense": {},
    "moe": dict(first_k_dense=1, n_experts=4, top_k=2, capacity_factor=4.0),
    "ssm": dict(ssm_state=16, ssm_headdim=16, ssm_chunk=4),
    "hybrid": dict(ssm_state=16, ssm_headdim=16, ssm_chunk=4,
                   shared_attn_period=2),
    "encdec": dict(n_enc_layers=2, n_dec_layers=2),
}
SRC_LEN = 12
# the acceptance mesh: lanes over data=4, KV heads/MLP/experts over model=2
MESH = make_mesh((4, 2), ("data", "model"))


def mk_engine(family, seed=0, mesh=None):
    cfg = ModelConfig(name=f"t-{family}", family=family,
                      **{**BASE, **FAMILY_OVER[family]})
    model = get_model(cfg)
    # same PRNGKey => identical params on both engines; the mesh engine
    # device_puts them to their TP placement without changing a byte
    params, _ = model.init(jax.random.PRNGKey(seed), cfg)
    return cfg, ServeEngine(cfg, params, max_new_tokens=6, stop_token=7,
                            mesh=mesh)


def mk_trace(rng, n, *, family="dense", d_model=64, shared_prefix=None):
    out, t = [], 0.0
    for _ in range(n):
        t += rng.exponential(1.5)
        prompt = rng.randint(1, 64, rng.randint(3, 14))
        if shared_prefix is not None and rng.rand() < 0.5:
            prompt = np.concatenate([shared_prefix, prompt])[:16]
        extras = None
        if family == "encdec":
            sl = int(rng.randint(2, SRC_LEN - 1))
            extras = {"src_emb": rng.randn(sl, d_model).astype(np.float32)}
        out.append((t, prompt, int(rng.randint(3, 8)), extras))
    return out


def serve(eng, trace, **kw):
    sched = ContinuousBatchingScheduler(eng, capacity=4, max_len=24, chunk=3,
                                        compact_threshold=0.5, **kw)
    for rid, (arrival, prompt, max_new, extras) in enumerate(trace):
        sp = (SamplingParams(temperature=0.8, top_p=0.9, seed=rid,
                             greedy=False) if rid % 3 == 0 else None)
        sched.submit(prompt, arrival=arrival, max_new_tokens=max_new,
                     sampling=sp, extras=extras)
    return sched.run(), sched.stats


def assert_identical(a, b, tag):
    assert sorted(a) == sorted(b), tag
    for rid in a:
        ta, tb = a[rid]["tokens"], b[rid]["tokens"]
        assert a[rid]["n_generated"] == b[rid]["n_generated"], (tag, rid)
        assert ta.dtype == tb.dtype and ta.tobytes() == tb.tobytes(), \
            (tag, rid, ta.tolist(), tb.tolist())
"""

_FAMILY_SCRIPT = _PRELUDE + r"""
cfg, eng0 = mk_engine(family)
_, eng1 = mk_engine(family, mesh=MESH)
assert eng1.cfg.act_shard == "tp"
rng = np.random.RandomState(11)
trace = mk_trace(rng, 6, family=family, d_model=cfg.d_model)
kw = {"src_len": SRC_LEN} if family == "encdec" else {}
base, st0 = serve(eng0, trace, **kw)
tp, st1 = serve(eng1, trace, **kw)
assert_identical(base, tp, family)
assert st0["dispatches"] == st1["dispatches"], (st0, st1)
assert st0["host_syncs"] == st1["host_syncs"], (st0, st1)
print(family + " sharded OK")
"""

_PAGED_SCRIPT = _PRELUDE + r"""
cfg, eng0 = mk_engine("dense", seed=1)
_, eng1 = mk_engine("dense", seed=1, mesh=MESH)
rng = np.random.RandomState(12)
trace = mk_trace(rng, 8, shared_prefix=rng.randint(1, 64, 8))
kw = dict(page_size=4, pool_pages=14, prefill_chunk=4)
base, st0 = serve(eng0, trace, **kw)
tp, st1 = serve(eng1, trace, **kw)
assert_identical(base, tp, "paged")
assert st0["dispatches"] == st1["dispatches"], (st0, st1)
# the trace genuinely exercised the hard paths on BOTH sides
for st in (st0, st1):
    assert st["prefill_chunks"] > 0 and st["prefix_hits"] > 0
    assert st["compactions"] > 0
# overlap (async one-sync-per-round loop) over the mesh too
tp_o, st_o = serve(eng1, trace, overlap=True, **kw)
assert_identical(base, tp_o, "paged-overlap")
assert st_o["host_syncs"] <= st_o["steps"] + 1, st_o
print("paged sharded OK")
"""


def _run(script):
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=580, env=_ENV)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


@pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid", "encdec"])
def test_sharded_serve_byte_identical(family):
    """Acceptance criterion: served tokens on the forced 8-device mesh are
    byte-identical to the unsharded fused loop, at the same dispatch count."""
    out = _run(f"family = {family!r}\n" + _FAMILY_SCRIPT)
    assert f"{family} sharded OK" in out


def test_sharded_serve_paged_prefix_chunked_compacting():
    """The full combination (paged + prefix sharing + chunked prefill +
    compaction + overlap) stays byte-identical under the mesh."""
    assert "paged sharded OK" in _run(_PAGED_SCRIPT)
