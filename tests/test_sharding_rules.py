"""Mesh-agnostic sharding resolution: divisibility fallbacks, axis reuse,
and the kv_heads -> kv_seq flash-decode fallback."""

import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax
from jax.sharding import PartitionSpec as P
from repro.dist import sharding as SH
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))

# TP weight: heads divisible by model -> sharded
assert SH.spec_for((256, 512), ("embed", "heads"), mesh) == P("data", "model")
# fused kv out dim not divisible by model=4 -> replicate (fallback)
assert SH.spec_for((64, 6), ("embed", "kv_heads"), mesh) == P("data", None)
# same mesh axis never reused within one array
assert SH.spec_for((8, 8), ("mlp", "heads"), mesh) == P("model", None)
# batch folds pod x data when present
mesh3 = make_mesh((2, 2, 4), ("pod", "data", "model"))
assert SH.spec_for((8, 128), ("batch", None), mesh3) == P(("pod", "data"), None)
# batch=1 (long_500k) -> fully replicated
assert SH.spec_for((1, 128), ("batch", None), mesh3) == P(None, None)
# kv cache: kv_heads=2 can't take model=4 => SEQ takes it (flash-decode)
spec = SH.spec_for((4, 2, 64, 32), ("batch", "act_kv_heads", "kv_seq", None), mesh)
assert spec == P("data", None, "model", None), spec
# kv_heads=4 divisible => heads take model, seq replicated
spec = SH.spec_for((4, 4, 64, 32), ("batch", "act_kv_heads", "kv_seq", None), mesh)
assert spec == P("data", "model", None, None), spec
# mesh-agnosticism: same logical axes resolve on ANY mesh shape
for shape, names in [((4,), ("data",)), ((2, 2), ("data", "model")),
                     ((2, 2, 2), ("pod", "data", "model"))]:
    m = make_mesh(shape, names)
    sp = SH.spec_for((16, 256, 512), ("layers", "embed", "mlp"), m)
    assert sp[0] is None

# ---- serve-shaped arrays (dist.serve: the scheduler's state layouts) ----
import numpy as np
from repro.dist import serve as DSRV
from repro.models.config import ModelConfig

# page pool (P, Hkv, ps, D): Hkv=2 does NOT divide model=4 -> the pool
# REPLICATES (divisibility fallback) — never an error, never a seq split
# (pools are gathered by table; their page dims must stay whole)
assert SH.spec_for((15, 2, 4, 16), (None, "kv_heads", None, None), mesh,
                   SH.SERVE_RULES) == P(None, None, None, None)
# Hkv=4 divides -> heads sharded over model
assert SH.spec_for((15, 4, 4, 16), (None, "kv_heads", None, None), mesh,
                   SH.SERVE_RULES) == P(None, "model", None, None)
# SERVE_RULES: no FSDP weight split over "data" while serving
assert SH.spec_for((256, 512), ("embed", "heads"), mesh,
                   SH.SERVE_RULES) == P(None, "model")

# full serve-cache resolution through dist.serve.cache_axes: a paged dense
# cache with GQA (Hkv=2 vs model=4) must replicate its pools but engage the
# kv_seq flash-decode fallback on the admission sub-cache's dense lane KV
cfg = ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=64)
paged = {"k_pages": np.zeros((2, 15, 2, 4, 16), np.float32),
         "v_pages": np.zeros((2, 15, 2, 4, 16), np.float32),
         "page_table": np.zeros((4, 6), np.int32),
         "pos": np.zeros((4,), np.int32)}
sh = DSRV.cache_shardings(cfg, paged, mesh)
assert sh["k_pages"].spec == P(None, None, None, None, None), sh["k_pages"].spec
assert sh["page_table"].spec == P("data", None)
assert sh["pos"].spec == P("data")
dense = {"k": np.zeros((2, 4, 2, 64, 16), np.float32),
         "v": np.zeros((2, 4, 2, 64, 16), np.float32),
         "pos": np.zeros((4,), np.int32)}
sh = DSRV.cache_shardings(cfg, dense, mesh)
# (L, B, Hkv, S, D): lanes over data; Hkv=2 can't take model=4 -> SEQ does
assert sh["k"].spec == P(None, "data", None, "model", None), sh["k"].spec
# Hkv=4 divides: heads take model, seq stays whole
dense4 = dict(dense, k=np.zeros((2, 4, 4, 64, 16), np.float32),
              v=np.zeros((2, 4, 4, 64, 16), np.float32))
sh = DSRV.cache_shardings(cfg.replace(n_kv_heads=4), dense4, mesh)
assert sh["k"].spec == P(None, "data", "model", None, None), sh["k"].spec
# ---- make_production_mesh degrades instead of raising on a dev box ----
import warnings
from repro.launch import mesh as M

with warnings.catch_warnings(record=True) as w:
    warnings.simplefilter("always")
    prod = M.make_production_mesh()
# 16 forced devices here: (16,16) halves largest-first down to (4,4)
assert dict(zip(prod.axis_names, prod.devices.shape)) == {"data": 4, "model": 4}, prod
assert any(issubclass(x.category, RuntimeWarning) for x in w), w
assert any("degraded" in str(x.message) for x in w), w
print("sharding rules OK")
"""


def test_sharding_rules_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            # force CPU: without this jax probes for
                            # accelerator plugins and can hang on
                            # network lookups in the bare subprocess
                            "JAX_PLATFORMS": "cpu",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "sharding rules OK" in r.stdout
