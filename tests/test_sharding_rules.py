"""Mesh-agnostic sharding resolution: divisibility fallbacks, axis reuse,
and the kv_heads -> kv_seq flash-decode fallback."""

import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax
from jax.sharding import PartitionSpec as P
from repro.dist import sharding as SH
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))

# TP weight: heads divisible by model -> sharded
assert SH.spec_for((256, 512), ("embed", "heads"), mesh) == P("data", "model")
# fused kv out dim not divisible by model=4 -> replicate (fallback)
assert SH.spec_for((64, 6), ("embed", "kv_heads"), mesh) == P("data", None)
# same mesh axis never reused within one array
assert SH.spec_for((8, 8), ("mlp", "heads"), mesh) == P("model", None)
# batch folds pod x data when present
mesh3 = make_mesh((2, 2, 4), ("pod", "data", "model"))
assert SH.spec_for((8, 128), ("batch", None), mesh3) == P(("pod", "data"), None)
# batch=1 (long_500k) -> fully replicated
assert SH.spec_for((1, 128), ("batch", None), mesh3) == P(None, None)
# kv cache: kv_heads=2 can't take model=4 => SEQ takes it (flash-decode)
spec = SH.spec_for((4, 2, 64, 32), ("batch", "act_kv_heads", "kv_seq", None), mesh)
assert spec == P("data", None, "model", None), spec
# kv_heads=4 divisible => heads take model, seq replicated
spec = SH.spec_for((4, 4, 64, 32), ("batch", "act_kv_heads", "kv_seq", None), mesh)
assert spec == P("data", "model", None, None), spec
# mesh-agnosticism: same logical axes resolve on ANY mesh shape
for shape, names in [((4,), ("data",)), ((2, 2), ("data", "model")),
                     ((2, 2, 2), ("pod", "data", "model"))]:
    m = make_mesh(shape, names)
    sp = SH.spec_for((16, 256, 512), ("layers", "embed", "mlp"), m)
    assert sp[0] is None
print("sharding rules OK")
"""


def test_sharding_rules_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            # force CPU: without this jax probes for
                            # accelerator plugins and can hang on
                            # network lookups in the bare subprocess
                            "JAX_PLATFORMS": "cpu",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "sharding rules OK" in r.stdout
