"""Speculative rejection sampling: the committed stream is distributed
EXACTLY as target-alone sampling (chi-squared-style tolerance on a toy
vocab), and greedy spec-decode is bit-unchanged by the sampling plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sample as S
from repro.core import predicate as P
from repro.models import ModelConfig, get_model
from repro.serve import speculative_decode

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=64, param_dtype="float32", compute_dtype="float32")


def _mk(seed=0, **over):
    cfg = ModelConfig(name="t", family="dense", **{**BASE, **over})
    model = get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed), cfg)
    return cfg, model, params


# ---------------------------------------------------------------------------
# the rejection algebra preserves the target distribution (unit level)
# ---------------------------------------------------------------------------

def _committed_first_token(draft, q, p, acc, fix):
    """Token the stream commits at window position 0: the draft token when
    position 0 was accepted, else the fix."""
    acc0 = np.asarray(acc)[:, 0]
    return np.where(acc0, np.asarray(draft)[:, 0], np.asarray(fix))


def test_rejection_first_token_marginal_matches_target():
    """Many i.i.d. lanes, fixed q != p: the marginal of the first committed
    token must be p (the losslessness theorem), checked with a chi-squared
    statistic on a toy vocab."""
    v, k, b = 6, 2, 20000
    rng = np.random.RandomState(0)
    q_row = rng.dirichlet(np.ones(v)).astype(np.float32)
    p_row = rng.dirichlet(np.ones(v)).astype(np.float32)
    q = jnp.broadcast_to(jnp.asarray(q_row), (b, k, v))
    p = jnp.broadcast_to(jnp.asarray(p_row), (b, k + 1, v))

    # draft proposals drawn from q with independent per-lane keys
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(b))
    gk = jax.vmap(lambda kk: jax.random.gumbel(kk, (k, v)))(keys)
    draft = jnp.argmax(jnp.log(q) + gk, axis=-1).astype(jnp.int32)

    round_key = jax.vmap(jax.random.PRNGKey)(jnp.arange(b) + 10_000_000)
    tgt_greedy = jnp.zeros((b, k + 1), jnp.int32)      # unused: no greedy lane
    acc, fix = S.speculative_accept(draft, q, p, tgt_greedy,
                                    jnp.zeros((b,), bool), round_key)
    tok = _committed_first_token(draft, q, p, acc, fix)

    counts = np.bincount(tok, minlength=v).astype(np.float64)
    expected = p_row.astype(np.float64) * b
    chi2 = ((counts - expected) ** 2 / np.maximum(expected, 1e-9)).sum()
    # chi-squared with v-1 = 5 dof: mean 5, std ~3.2; 30 is a ~7.8-sigma
    # guard band — fails only on a real distribution bug (test is seeded)
    assert chi2 < 30.0, (chi2, counts / b, p_row)
    # and NOT the proposal distribution (sanity that the test can fail)
    chi2_q = ((counts - q_row * b) ** 2 / np.maximum(q_row * b, 1e-9)).sum()
    assert chi2_q > 100.0


def test_rejection_identity_distributions_always_accept():
    """q == p => the acceptance ratio is identically 1: the FFR partition
    never faults (zero wasted speculation against a perfect draft)."""
    v, k, b = 8, 3, 256
    rng = np.random.RandomState(1)
    dist = rng.dirichlet(np.ones(v)).astype(np.float32)
    q = jnp.broadcast_to(jnp.asarray(dist), (b, k, v))
    p = jnp.broadcast_to(jnp.asarray(dist), (b, k + 1, v))
    draft = jnp.asarray(rng.randint(0, v, (b, k)), jnp.int32)
    round_key = jax.vmap(jax.random.PRNGKey)(jnp.arange(b))
    acc, fix = S.speculative_accept(draft, q, p, jnp.zeros((b, k + 1),
                                                           jnp.int32),
                                    jnp.zeros((b,), bool), round_key)
    assert bool(jnp.all(acc))
    # bonus draw comes from p (position K residual is p itself)
    assert np.asarray(fix).min() >= 0 and np.asarray(fix).max() < v


def test_residual_dist_normalises_and_falls_back():
    p = jnp.asarray([[0.5, 0.3, 0.2]], jnp.float32)
    q = jnp.asarray([[0.2, 0.5, 0.3]], jnp.float32)
    r = np.asarray(S.residual_dist(p, q))
    want = np.maximum(np.asarray(p) - np.asarray(q), 0)
    want = want / want.sum()
    np.testing.assert_allclose(r, want, rtol=1e-6)
    # p == q: residual has no mass, falls back to p
    np.testing.assert_allclose(np.asarray(S.residual_dist(p, p)),
                               np.asarray(p), rtol=1e-6)


# ---------------------------------------------------------------------------
# end-to-end speculative decoding under sampling
# ---------------------------------------------------------------------------

def test_greedy_spec_decode_unchanged_by_sampling_plumbing():
    """sampling=None and sampling=all-greedy commit identical streams (and
    the None path is the pre-sampling code path, so both equal the old
    engine's output — asserted against target-alone greedy elsewhere)."""
    tcfg, _, tparams = _mk(seed=2)
    dcfg, _, _ = _mk(seed=0, n_layers=1, d_model=32, d_ff=64,
                     n_heads=2, n_kv_heads=1)
    dparams = get_model(dcfg).init(jax.random.PRNGKey(3), dcfg)[0]
    prompts = jnp.asarray(np.random.RandomState(2).randint(1, 64, (3, 8)))
    a, astats = speculative_decode(tcfg, tparams, dcfg, dparams, prompts,
                                   n_tokens=8, k_draft=3)
    g, gstats = speculative_decode(tcfg, tparams, dcfg, dparams, prompts,
                                   n_tokens=8, k_draft=3,
                                   sampling=[S.SamplingParams(greedy=True,
                                                              seed=i)
                                             for i in range(3)])
    np.testing.assert_array_equal(np.asarray(a), np.asarray(g))
    np.testing.assert_array_equal(np.asarray(astats["n_generated"]),
                                  np.asarray(gstats["n_generated"]))


def test_sampled_spec_decode_deterministic_and_perfect_draft_accepts_all():
    """draft == target under temperature sampling: q == p per position, so
    rejection never fires (mean accepted == k) and the stream is
    seed-reproducible."""
    tcfg, _, tparams = _mk(seed=4)
    prompts = jnp.asarray(np.random.RandomState(4).randint(1, 64, (2, 6)))
    spec = [S.SamplingParams(temperature=0.9, top_p=0.95, seed=21 + i,
                             greedy=False) for i in range(2)]
    a, astats = speculative_decode(tcfg, tparams, tcfg, tparams, prompts,
                                   n_tokens=6, k_draft=2, sampling=spec)
    b_, _ = speculative_decode(tcfg, tparams, tcfg, tparams, prompts,
                               n_tokens=6, k_draft=2, sampling=spec)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
    assert astats["mean_accepted"] == pytest.approx(2.0)


def test_mixed_greedy_and_sampled_lanes_spec_decode():
    """Greedy lanes keep the exact-match algebra while stochastic lanes use
    rejection — in one batched call; the greedy lane's stream equals its
    sampling=None stream."""
    tcfg, _, tparams = _mk(seed=5)
    dcfg, _, _ = _mk(seed=1, n_layers=1, d_model=32, d_ff=64,
                     n_heads=2, n_kv_heads=1)
    dparams = get_model(dcfg).init(jax.random.PRNGKey(6), dcfg)[0]
    prompts = jnp.asarray(np.random.RandomState(5).randint(1, 64, (2, 7)))
    ref, _ = speculative_decode(tcfg, tparams, dcfg, dparams, prompts,
                                n_tokens=7, k_draft=2)
    mix, _ = speculative_decode(
        tcfg, tparams, dcfg, dparams, prompts, n_tokens=7, k_draft=2,
        sampling=[S.SamplingParams(greedy=True),
                  S.SamplingParams(temperature=1.0, seed=9, greedy=False)])
    np.testing.assert_array_equal(np.asarray(mix[0]), np.asarray(ref[0]))


def test_accept_prefix_is_monotone_under_rejection_bits():
    """The acceptance predicate is still a brkb partition: nothing after the
    first rejection is accepted."""
    v, k, b = 4, 4, 512
    rng = np.random.RandomState(7)
    q = jax.nn.softmax(jnp.asarray(rng.randn(b, k, v), jnp.float32), -1)
    p = jax.nn.softmax(jnp.asarray(rng.randn(b, k + 1, v), jnp.float32), -1)
    draft = jnp.asarray(rng.randint(0, v, (b, k)), jnp.int32)
    round_key = jax.vmap(jax.random.PRNGKey)(jnp.arange(b))
    acc, _ = S.speculative_accept(draft, q, p, jnp.zeros((b, k + 1),
                                                         jnp.int32),
                                  jnp.zeros((b,), bool), round_key)
    accn = np.asarray(acc)
    n_acc = np.asarray(P.cntp(jnp.asarray(accn)))
    for i in range(b):
        np.testing.assert_array_equal(accn[i, :n_acc[i]], True)
        np.testing.assert_array_equal(accn[i, n_acc[i]:], False)
