"""End-to-end integration: loss decreases; microbatching is exact; elastic
checkpoint restore re-shards across meshes."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticLM
from repro.models import ModelConfig
from repro.train.step import init_state, make_train_step

CFG = ModelConfig(name="it", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  param_dtype="float32", compute_dtype="float32")


def _batch(data, step, b=8):
    tokens, labels, lens = data.batch(step, b)
    return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels),
            "lens": jnp.asarray(lens)}


def test_loss_decreases():
    state, _ = init_state(jax.random.PRNGKey(0), CFG)
    step = jax.jit(make_train_step(CFG, peak_lr=2e-3, warmup=5, total=30))
    data = SyntheticLM(CFG.vocab_size, 64, seed=0)
    losses = []
    for s in range(25):
        state, m = step(state, _batch(data, s))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.9, losses


def test_microbatched_grads_match_full_batch():
    """mb=4 accumulation == one full-batch step (same init, same data)."""
    data = SyntheticLM(CFG.vocab_size, 32, seed=1)
    batch = _batch(data, 0, b=8)
    s1, _ = init_state(jax.random.PRNGKey(2), CFG)
    s2 = jax.tree.map(lambda x: x, s1)
    full = jax.jit(make_train_step(CFG, microbatch=1))
    micro = jax.jit(make_train_step(CFG, microbatch=4))
    out1, m1 = full(s1, batch)
    out2, m2 = micro(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(out1["params"]),
                    jax.tree.leaves(out2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-6)


def test_elastic_restore_across_meshes():
    """Save unsharded -> restore onto a 4-device mesh with NamedShardings."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.launch.mesh import make_mesh

tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
        "b": jnp.arange(8, dtype=jnp.float32)}
d = tempfile.mkdtemp()
save_checkpoint(d, 3, tree)

mesh = make_mesh((2, 2), ("data", "model"))
sh = {"w": NamedSharding(mesh, P("data", "model")),
      "b": NamedSharding(mesh, P("model"))}
like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
out, step = restore_checkpoint(d, like, shardings=sh)
assert step == 3
np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
assert out["w"].sharding.spec == P("data", "model")
print("ELASTIC-OK")
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            # force CPU: without this jax probes for
                            # accelerator plugins and can hang on
                            # network lookups in the bare subprocess
                            "JAX_PLATFORMS": "cpu",
                            "HOME": "/root"})
    assert "ELASTIC-OK" in r.stdout, r.stdout + r.stderr
